"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference, plus the
jnp-path timing that is the CPU-meaningful number.  Interpret-mode wall time
is NOT TPU performance — the TPU claim is the VMEM/BlockSpec structure
checked here for fit, and the roofline table in EXPERIMENTS.md."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.segment_combine.ops import (pack_edges, pack_values,
                                               segment_combine)
from repro.kernels.ssd_scan.ops import ssd_scan

VMEM_BUDGET = 16 * 2 ** 20  # v5e ~16MB/core usable


def _vmem_report():
    print("# kernel VMEM working sets (bytes, must be << 16MiB)")
    eb, nb = 512, 256
    seg = (eb * nb + eb + nb) * 4
    bq = bk = 512
    d = 256
    fla = (bq * d + 2 * bk * d + bq * bk + 2 * bq + bq * d) * 4
    q, p, n = 128, 64, 128
    ssd = (q * (p + 2 * n + 1) + q * q + p * n * 2 + q * p) * 4
    for name, b in [("segment_combine", seg), ("flash_attention", fla),
                    ("ssd_scan", ssd)]:
        assert b < VMEM_BUDGET, (name, b)
        print(f"vmem.{name},{b},fits=True")


def _bench_channel_backends():
    """Dense vmap-scatter vs plan-driven combine on one broadcast step —
    the tentpole comparison (same inbox, same stats, different memory)."""
    from repro.core.channels import broadcast
    from repro.core import plan as planlib
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(40_000, avg_deg=8, seed=0, alpha=1.8).symmetrized()
    M = 16
    pg = partition(g, M, tau=60, seed=0)
    vals = jnp.where(pg.vmask, 1.0, 0.0)
    results = {}
    for backend in ("dense", "pallas"):
        fn = jax.jit(lambda v: broadcast(pg, v, pg.vmask, op="min",
                                         backend=backend)[0])
        fn(vals).block_until_ready()
        _, secs = timed(lambda: fn(vals).block_until_ready(), repeat=3)
        results[backend] = secs
        row(f"chan.broadcast.{backend}.n40k", secs,
            f"M={M};E={g.m}")
    plan = planlib.get_plan(pg, "eg")
    dense_bytes = M * pg.n_pad * 4
    row("chan.broadcast.mem", 0.0,
        f"dense_partial_bytes={dense_bytes};"
        f"plan_packed_bytes={plan.packed_bytes};"
        f"speed_ratio={results['dense'] / max(results['pallas'], 1e-9):.2f}")


def _bench_vector_feature_sweep():
    """(lanes, F) feature-blocked combine: pallas (interpret) vs jnp ref
    across payload widths.  Timings are INTERLEAVED best-of — variant A
    and B alternate within each round (single-core container: never run
    the contenders concurrently, and let clock drift hit both alike)."""
    import time

    from repro.kernels.segment_combine.kernel import segment_combine_blocks
    from repro.kernels.segment_combine.ref import segment_combine_blocks_ref

    rng = np.random.RandomState(1)
    nb, eb, n_blocks = 256, 512, 8
    idx = jnp.asarray(rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32))
    for F in (1, 8, 32, 128):
        vals = jnp.asarray(rng.randn(n_blocks, eb, F).astype(np.float32))
        fk = jax.jit(lambda v, i: segment_combine_blocks(v, i, "sum", nb))
        fr = jax.jit(
            lambda v, i: segment_combine_blocks_ref(v, i, "sum", nb))
        fk(vals, idx).block_until_ready()
        fr(vals, idx).block_until_ready()
        best_k = best_r = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fk(vals, idx).block_until_ready()
            best_k = min(best_k, time.perf_counter() - t0)
            t0 = time.perf_counter()
            fr(vals, idx).block_until_ready()
            best_r = min(best_r, time.perf_counter() - t0)
        lanes = n_blocks * eb
        row(f"kern.segment_combine.vec.F{F}.pallas", best_k,
            f"lanes={lanes};nb={nb}")
        row(f"kern.segment_combine.vec.F{F}.ref_jnp", best_r,
            f"pallas_over_ref={best_k / max(best_r, 1e-9):.2f}")


def run():
    _vmem_report()
    rng = np.random.RandomState(0)

    # segment_combine: graph-scale message combining
    E, N = 200_000, 16_384
    dst = rng.randint(0, N, E)
    vals = rng.randn(E).astype(np.float32)
    (order, idxl), pack_secs = timed(pack_edges, dst, N, nb=256, repeat=3)
    row("kern.pack_edges.vectorized.E200k", pack_secs, f"E={E};N={N}")
    pv = jnp.asarray(pack_values(vals, order, idxl, "sum"))
    idxl = jnp.asarray(idxl)
    f_ref = jax.jit(lambda v, i: segment_combine(v, i, "sum", 256, N,
                                                 use_kernel=False))
    f_ref(pv, idxl).block_until_ready()
    _, secs = timed(lambda: f_ref(pv, idxl).block_until_ready(), repeat=3)
    row("kern.segment_combine.ref_jnp.E200k", secs, f"E={E};N={N}")

    # feature-blocked (lanes, F) payload sweep
    _bench_vector_feature_sweep()

    # channel-layer backend comparison (dense scatters vs message plans)
    _bench_channel_backends()

    # flash attention (jnp ref path = CPU-meaningful; kernel checked in tests)
    B, S, H, K, hd = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, K, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, K, hd), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, use_kernel=False))
    f(q, k, v).block_until_ready()
    _, secs = timed(lambda: f(q, k, v).block_until_ready(), repeat=3)
    flops = 4 * B * S * S * H * hd / 2
    row("kern.flash_attention.ref_jnp.S1024", secs,
        f"gflops_s={flops / secs / 1e9:.1f}")

    # ssd scan
    b, s, h, p, n = 1, 2048, 8, 64, 64
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.randn(h), jnp.float32) * 0.3)
    Bm = jnp.asarray(rng.randn(b, s, 1, n), jnp.float32)
    Cm = jnp.asarray(rng.randn(b, s, 1, n), jnp.float32)
    f = jax.jit(lambda *a: ssd_scan(*a, chunk=128, use_kernel=False))
    f(x, dt, A, Bm, Cm).block_until_ready()
    _, secs = timed(lambda: f(x, dt, A, Bm, Cm).block_until_ready(),
                    repeat=3)
    row("kern.ssd_scan.ref_jnp.S2048", secs, f"bhpn={b}x{h}x{p}x{n}")
    return True


if __name__ == "__main__":
    run()

"""gSpMM channel join vs the dense segment-sum baseline at GNN scale,
plus the end-to-end GCN training check (PR 8).

Measures one ``u_mul_e_sum`` aggregation — feats ``(n, F=32)`` on an
n=200k power-law graph — two ways:

* ``dense_segment_sum``: the straight-line XLA formulation,
  ``zeros.at[dst].add(x[src] * w)`` over the flat edge list (what a
  GNN library does on one device);
* ``channel_join``: the same aggregation as a sharded message-channel
  join (sender-side combining + mirror fan-out) over a D=8 device mesh
  via ``exec.build_apply``.

Numeric parity between the two is **hard-asserted on every run** (report
mode included) — the join is an execution strategy, never a different
operator.  ``--gate`` additionally asserts the GCN trains: 5 full-graph
epochs at n=200k / F=32 / devices=8 must strictly decrease the loss.

Methodology (single-CPU runners): both programs are compiled ONCE and
timed samples are INTERLEAVED — a co-tenant degrades both contenders
instead of poisoning one; best sample per program is kept.  Wall-clock
on a forced 8-device CPU host measures collective scheduling overhead,
not network overlap — the paper-relevant numbers are the message/lane
accounting also recorded here.

    python benchmarks/bench_gspmm.py                 # report mode
    python benchmarks/bench_gspmm.py --gate          # CI hard gate
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# jax-free: safe to import before the device flags are set
from repro.launch.xla_flags import force_host_devices  # noqa: E402


def gspmm_bench(n: int = 200_000, feat_dim: int = 32, workers: int = 32,
                devices: int = 8, epochs: int = 5, repeat: int = 3,
                out: str = "BENCH_gspmm.json", gate: bool = False) -> dict:
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core import exec as exec_mod
    from repro.core import gspmm
    from repro.core.cost_model import choose_tau
    from repro.graph import generators as gen
    from repro.graph.structs import partition
    from repro.train.gcn import normalize_adjacency, train_gcn

    g = gen.powerlaw(n, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    g = normalize_adjacency(g)
    tau = choose_tau(g.out_degrees(), workers)
    pg = partition(g, workers, tau=tau, seed=0, layout="csr")
    rng = np.random.RandomState(0)
    feats = jnp.asarray(
        rng.randn(pg.M, pg.n_loc, feat_dim).astype(np.float32))
    src = jnp.asarray(pg.perm[g.src])
    dst = jnp.asarray(pg.perm[g.dst])
    w = jnp.asarray(g.weight.astype(np.float32))

    report = {"n": g.n, "m": g.m, "F": feat_dim, "workers": workers,
              "devices": devices, "tau": int(tau), "layout": "csr",
              "kind": "u_mul_e_sum", "programs": {}}

    # -- dense baseline: flat scatter-add, one device ---------------------
    def dense(x):
        xf = x.reshape(pg.n_pad, feat_dim)
        outf = jnp.zeros_like(xf).at[dst].add(xf[src] * w[:, None])
        return outf.reshape(x.shape)

    f_dense = jax.jit(dense)

    # -- channel join: sharded mesh, compiled once ------------------------
    def mk(gctx):
        def fn(x):
            return gspmm.gspmm_stats(gctx, "u_mul_e_sum", x)
        return fn

    t0 = time.perf_counter()
    f_join, arrays = exec_mod.build_apply(pg, mk, (feats,),
                                          devices=devices)
    join_out, stats = jax.block_until_ready(f_join(arrays, (feats,)))
    compile_join = time.perf_counter() - t0
    t0 = time.perf_counter()
    dense_out = jax.block_until_ready(f_dense(feats))
    compile_dense = time.perf_counter() - t0

    # -- parity: HARD assert, report mode included ------------------------
    err = float(jnp.max(jnp.abs(join_out - dense_out)))
    scale = float(jnp.max(jnp.abs(dense_out))) or 1.0
    report["parity_max_abs_err"] = err
    report["parity_rel_err"] = err / scale
    assert err <= 1e-4 * scale + 1e-5, (
        f"channel join diverged from dense segment-sum: max |delta| "
        f"{err:.3e} vs scale {scale:.3e}")

    # -- interleaved best-of timing ---------------------------------------
    best = {"dense_segment_sum": float("inf"), "channel_join": float("inf")}
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(f_dense(feats))
        best["dense_segment_sum"] = min(best["dense_segment_sum"],
                                        time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f_join(arrays, (feats,)))
        best["channel_join"] = min(best["channel_join"],
                                   time.perf_counter() - t0)
    report["programs"]["dense_segment_sum"] = {
        "best_s": round(best["dense_segment_sum"], 4),
        "compile_and_first_run_s": round(compile_dense, 3)}
    report["programs"]["channel_join"] = {
        "best_s": round(best["channel_join"], 4),
        "compile_and_first_run_s": round(compile_join, 3),
        "msgs_total": int(stats["msgs_total"]),
        "msgs_combined": int(stats["msgs_combined"]),
        "msgs_mirror": int(stats["msgs_mirror"]),
        "msgs_basic": int(stats["msgs_basic"])}
    print(f"[gspmm-bench] n={g.n} F={feat_dim} D={devices}: dense "
          f"{best['dense_segment_sum']:.3f}s, channel join "
          f"{best['channel_join']:.3f}s, parity |delta| {err:.2e}, "
          f"msgs {int(stats['msgs_total']):,d} vs basic "
          f"{int(stats['msgs_basic']):,d}", flush=True)

    # -- GCN end-to-end: loss must decrease over >= 5 epochs --------------
    t0 = time.perf_counter()
    _, losses = train_gcn(pg, feat_dim=feat_dim, hidden=64, n_classes=8,
                          epochs=epochs, lr=1e-2, seed=0, devices=devices)
    gcn_s = time.perf_counter() - t0
    report["gcn"] = {"epochs": epochs, "hidden": 64, "classes": 8,
                     "loss_history": [round(x, 5) for x in losses],
                     "wall_s": round(gcn_s, 2)}
    print(f"[gspmm-bench] gcn: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {epochs} epochs ({gcn_s:.1f}s incl. compile)", flush=True)

    # write BEFORE the gate asserts: the JSON is the failure diagnostic
    Path(out).write_text(json.dumps(report, indent=2))
    print(f"[gspmm-bench] report -> {out}")
    if gate:
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], (
            f"GCN loss did not decrease: {losses[0]:.5f} -> "
            f"{losses[-1]:.5f}")
        print("[gspmm-bench] GATE OK: parity exact within tolerance and "
              "GCN loss decreased")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="hard-fail unless the GCN loss decreases over "
                         "the epoch budget (join/dense parity is "
                         "asserted on every run)")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--out", default="BENCH_gspmm.json")
    args = ap.parse_args()
    force_host_devices(args.devices)    # before the first jax import
    gspmm_bench(n=args.n, feat_dim=args.feat_dim, workers=args.workers,
                devices=args.devices, epochs=args.epochs,
                repeat=args.repeat, out=args.out, gate=args.gate)


if __name__ == "__main__":
    main()

"""Paper Fig. 12: mirroring thresholds x {PageRank, Hash-Min} x graphs.

Columns reproduced: Pregel-noM (combiner only), Pregel-noMC (no combiner —
the message count without sender-side combining), mirroring at tau in
{1, 10, 100, 1000}, and the Theorem-2 cost-model tau.  Metrics: message
count (exact), per-worker balance (max/mean), wall seconds (CPU, relative).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import paper_graphs, row, timed
from repro.algorithms.hashmin import hashmin
from repro.algorithms.pagerank import pagerank
from repro.core.cost_model import choose_tau, expected_messages_mirrored
from repro.graph.structs import partition
from repro.core.cost_model import straggler_report

M = 16
PR_ITERS = 10


def _run(algo, pg, mirror):
    if algo == "pagerank":
        return pagerank(pg, n_iters=PR_ITERS, tol=0.0, use_mirroring=mirror)
    return hashmin(pg, use_mirroring=mirror)


def run(scale=20_000):
    print("# Fig12: name,us_per_call,msgs|msgs_noMC|balance|tau")
    graphs = paper_graphs(scale)
    for gname, algo in [("btc_like", "hashmin"), ("usa_like", "hashmin"),
                        ("twitter_like", "pagerank"),
                        ("webuk_like", "pagerank")]:
        g = graphs[gname]
        if algo == "hashmin":
            g = g.symmetrized()
        deg = g.out_degrees()
        tau_auto = choose_tau(deg, M)
        taus = [("noM", None), ("t1", 1), ("t10", 10), ("t100", 100),
                ("t1000", 1000), ("costmodel", tau_auto)]
        results = {}
        for tname, tau in taus:
            pg = partition(g, M, tau=tau, seed=0)
            mirror = tau is not None
            (res, stats, n), secs = timed(_run, algo, pg, mirror)
            msgs = int(stats["msgs_total"] if mirror
                       else stats["msgs_combined"])
            no_mc = int(stats["msgs_basic"])
            bal = straggler_report(np.asarray(
                stats["per_worker_total"] if mirror
                else stats["per_worker_combined"]))
            results[tname] = msgs
            tau_str = tau if tau is not None else "inf"
            row(f"fig12.{algo}.{gname}.{tname}", secs,
                f"msgs={msgs};noMC={no_mc};maxmean={bal['max_over_mean']:.2f}"
                f";tau={tau_str};supersteps={int(n)}")
        # paper claim: cost-model tau near-optimal
        best = min(results.values())
        assert results["costmodel"] <= 1.3 * best, results
    return True


if __name__ == "__main__":
    run()

"""Persistent graph service: sustained query throughput + the
mutation-fold speedup gate (PR 9).

Boots a resident :class:`repro.core.service.GraphService` on an n=200k
power-law graph (csr layout, edge-balanced, D=8 mesh) and measures:

* **sustained queries/sec** over mixed SSSP + PPR + ego batches at the
  FIXED padding buckets — executors are compiled once at warmup and the
  service's trace counter is hard-asserted flat across every measured
  batch (admission must never re-trace);
* **mutation fold vs full re-partition** at 1% edge churn: the
  incremental ``fold_delta`` (delta-CSR segments merged under the pinned
  perm) against ``partition(apply_delta(g, delta))`` from scratch.
  ``--gate`` HARD-asserts the fold is >= 10x faster — the whole point of
  keeping the graph resident;
* the full epoch-barrier cost as the service pays it (fold + host edge
  list + re-pad shard arrays under the frozen profile).

Methodology (single-CPU runners): fold and full-repartition samples are
INTERLEAVED and best-of kept, so a co-tenant degrades both contenders
instead of poisoning one.  The JSON is written BEFORE the gate asserts —
it is the diagnostic when the gate fails.

    python benchmarks/bench_serve.py                 # report mode
    python benchmarks/bench_serve.py --gate          # CI hard gate
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# jax-free: safe to import before the device flags are set
from repro.launch.xla_flags import force_host_devices  # noqa: E402


def churn_delta(g, frac, seed):
    """Symmetric 1%-style churn: remove ``frac`` of the undirected
    edges, add as many random ones (both directions)."""
    import numpy as np
    from repro.graph.structs import EdgeDelta
    rng = np.random.RandomState(seed)
    lo = np.minimum(g.src, g.dst)
    hi = np.maximum(g.src, g.dst)
    key = np.unique(lo.astype(np.int64) * g.n + hi)
    k = max(int(len(key) * frac), 1)
    ridx = rng.choice(len(key), size=k, replace=False)
    a_s = rng.randint(0, g.n, size=k)
    a_d = rng.randint(0, g.n, size=k)
    keep = a_s != a_d
    return EdgeDelta(
        add_src=a_s[keep], add_dst=a_d[keep],
        add_w=rng.rand(int(keep.sum())).astype(np.float32) + 0.01,
        rem_src=key[ridx] // g.n,
        rem_dst=key[ridx] % g.n).symmetrized()


def serve_bench(n: int = 200_000, workers: int = 32, devices: int = 8,
                batch: int = 32, rounds: int = 3, churn: float = 0.01,
                repeat: int = 5, ppr_iters: int = 10,
                buckets=(4, 16), out: str = "BENCH_serve.json",
                gate: bool = False) -> dict:
    import numpy as np

    from repro.api import EngineConfig
    from repro.core.service import GraphClient, GraphService, Query
    from repro.graph import generators as gen
    from repro.graph.structs import apply_delta, fold_delta, partition

    g = gen.powerlaw(n, avg_deg=8, seed=5, alpha=1.8,
                     weighted=True).symmetrized()
    cfg = EngineConfig(layout="csr", balance="edges", devices=devices)
    t0 = time.perf_counter()
    svc = GraphService(g, M=workers, config=cfg, buckets=buckets,
                       ppr_iters=ppr_iters, max_supersteps=256)
    t_boot = time.perf_counter() - t0
    t0 = time.perf_counter()
    svc.warmup()
    t_warm = time.perf_counter() - t0
    client = GraphClient(svc)
    report = {"n": g.n, "m": g.m, "workers": workers, "devices": devices,
              "layout": "csr", "balance": "edges",
              "buckets": list(svc.buckets), "batch": batch,
              "ppr_iters": ppr_iters, "churn": churn,
              "boot_s": round(t_boot, 2), "warmup_s": round(t_warm, 2),
              "warmup_traces": svc.traces}
    print(f"[serve-bench] resident n={g.n} m={g.m} M={workers} "
          f"D={devices}: boot {t_boot:.2f}s, warmup {t_warm:.2f}s "
          f"({svc.traces} traces)", flush=True)

    # -- sustained mixed-batch throughput, zero re-traces -----------------
    rng = np.random.RandomState(0)
    traces0 = svc.traces
    best_qps, times = 0.0, []
    for r in range(rounds):
        k = batch // 3
        queries = ([Query("sssp", int(s)) for s in
                    rng.randint(0, g.n, size=k)]
                   + [Query("ppr", int(s)) for s in
                      rng.randint(0, g.n, size=k)]
                   + [Query("ego", int(s)) for s in
                      rng.randint(0, g.n, size=batch - 2 * k)])
        t0 = time.perf_counter()
        client.request(queries)
        dt = time.perf_counter() - t0
        times.append(dt)
        best_qps = max(best_qps, batch / dt)
        print(f"[serve-bench] round {r}: {batch} queries in {dt:.2f}s "
              f"({batch / dt:.1f} q/s, bucket "
              f"{svc.last_batch['bucket']}, "
              f"{svc.last_pump['n_supersteps']} supersteps)", flush=True)
    assert svc.traces == traces0, (
        f"measured serving re-traced: {svc.traces - traces0}")
    report["serving"] = {
        "rounds": rounds, "round_s": [round(t, 3) for t in times],
        "best_qps": round(best_qps, 2),
        "supersteps_last": int(svc.last_pump["n_supersteps"]),
        "retraces": svc.traces - traces0}

    # -- fold vs full re-partition, interleaved best-of -------------------
    pg, g_now = svc.pg, svc.snapshot_graph()
    best = {"fold_s": float("inf"), "full_repartition_s": float("inf")}
    for i in range(repeat):
        delta = churn_delta(g_now, churn, seed=100 + i)
        t0 = time.perf_counter()
        folded = fold_delta(pg, delta)
        best["fold_s"] = min(best["fold_s"], time.perf_counter() - t0)
        t0 = time.perf_counter()
        fresh = partition(apply_delta(g_now, delta), workers, tau=pg.tau,
                          layout="csr", balance="edges")
        best["full_repartition_s"] = min(best["full_repartition_s"],
                                         time.perf_counter() - t0)
        if i == 0:  # parity spot-check rides along with the timing
            import numpy as _np
            ref = partition(apply_delta(g_now, delta), workers,
                            tau=pg.tau, layout="csr", balance="edges",
                            perm=pg.perm)
            for f in ("eg_src", "eg_dst", "all_src", "all_dst", "deg"):
                assert _np.array_equal(_np.asarray(getattr(folded, f)),
                                       _np.asarray(getattr(ref, f))), f
    speedup = best["full_repartition_s"] / best["fold_s"]
    report["fold"] = {k: round(v, 4) for k, v in best.items()}
    report["fold"]["speedup"] = round(speedup, 2)
    print(f"[serve-bench] 1% churn: fold {best['fold_s'] * 1e3:.1f}ms vs "
          f"full re-partition {best['full_repartition_s'] * 1e3:.1f}ms "
          f"-> {speedup:.1f}x", flush=True)

    # -- the barrier as the service pays it -------------------------------
    delta = churn_delta(g_now, churn, seed=999)
    svc.mutate(delta)
    t0 = time.perf_counter()
    svc.pump()                      # folds + re-pads arrays, no queries
    t_barrier = time.perf_counter() - t0
    assert svc.traces == traces0, "the epoch barrier re-traced"
    report["fold"]["service_barrier_s"] = round(t_barrier, 3)
    print(f"[serve-bench] in-service epoch barrier (fold + host edges + "
          f"reshard): {t_barrier:.2f}s, zero re-traces", flush=True)

    # write BEFORE the gate asserts: the JSON is the failure diagnostic
    Path(out).write_text(json.dumps(report, indent=2))
    print(f"[serve-bench] report -> {out}")
    if gate:
        assert speedup >= 10.0, (
            f"mutation fold only {speedup:.1f}x faster than full "
            f"re-partition (gate: >= 10x)")
        print("[serve-bench] GATE OK: fold >= 10x faster than full "
              "re-partition, serving never re-traced")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="hard-fail unless the 1%%-churn fold beats a "
                         "full re-partition by >= 10x (zero-re-trace is "
                         "asserted on every run)")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--churn", type=float, default=0.01)
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--ppr-iters", type=int, default=10)
    ap.add_argument("--buckets", type=int, nargs="+", default=[4, 16])
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    force_host_devices(args.devices)    # before the first jax import
    serve_bench(n=args.n, workers=args.workers, devices=args.devices,
                batch=args.batch, rounds=args.rounds, churn=args.churn,
                repeat=args.repeat, ppr_iters=args.ppr_iters,
                buckets=tuple(args.buckets), out=args.out, gate=args.gate)


if __name__ == "__main__":
    main()

"""Shared benchmark utilities."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.graph import generators as gen  # noqa: E402


def timed(fn, *args, repeat: int = 1, **kw):
    """Returns (result, best_seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def paper_graphs(scale: int = 20_000):
    """CPU-scale analogs of the paper's datasets (Fig. 11):
    skewed (BTC/Twitter/LJ), high-avg-degree (WebUK), road (USA)."""
    return {
        "btc_like": gen.powerlaw(scale, avg_deg=5, alpha=1.7,
                                 seed=0).symmetrized(),
        "twitter_like": gen.powerlaw(scale, avg_deg=12, alpha=1.9, seed=1),
        "webuk_like": gen.erdos(scale, avg_deg=20, seed=2),
        "usa_like": gen.grid_road(int(np.sqrt(scale)), seed=3,
                                  weighted=True),
    }


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")

"""Paper Fig. 13: request-respond vs basic Pregel on attribute broadcast,
S-V, and MSF (message counts are exact; both counts come from one run since
Ch_req returns identical values, only the message accounting differs)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import paper_graphs, row, timed
from repro.algorithms.attr_bcast import attribute_broadcast
from repro.algorithms.msf import msf
from repro.algorithms.sv import sv
from repro.graph.structs import partition
from repro.core.cost_model import straggler_report

M = 16


def run(scale=20_000):
    print("# Fig13: name,us_per_call,rr|basic|reduction|balance")
    graphs = paper_graphs(scale)

    for gname in ["webuk_like", "btc_like", "twitter_like"]:
        g = graphs[gname].symmetrized()
        pg = partition(g, M, tau=None, seed=0)
        attr = jnp.arange(pg.n_pad, dtype=jnp.float32).reshape(pg.M, pg.n_loc)
        (out, stats), secs = timed(attribute_broadcast, pg, attr)
        rr, basic = int(stats["msgs_rr"]), int(stats["msgs_basic"])
        row(f"fig13.attr_bcast.{gname}", secs,
            f"rr={rr};basic={basic};x={basic / max(rr, 1):.2f}")

    for gname in ["usa_like", "btc_like"]:
        g = graphs[gname].symmetrized()
        pg = partition(g, M, tau=None, seed=0)
        (labels, stats, n), secs = timed(sv, pg)
        rr, basic = int(stats["msgs_rr"]), int(stats["msgs_basic"])
        bal_rr = straggler_report(np.asarray(stats["per_worker_rr"]))
        bal_b = straggler_report(np.asarray(stats["per_worker_basic"]))
        row(f"fig13.sv.{gname}", secs,
            f"rr={rr};basic={basic};x={basic / max(rr, 1):.2f}"
            f";maxmean_rr={bal_rr['max_over_mean']:.2f}"
            f";maxmean_basic={bal_b['max_over_mean']:.2f};rounds={int(n)}")

    for gname in ["usa_like", "btc_like"]:
        g = graphs[gname]
        if g.weight is None:
            rng = np.random.RandomState(1)
            g.weight = rng.rand(g.m).astype(np.float32) + 0.01
        g = g.symmetrized()
        pg = partition(g, M, tau=None, seed=0)
        (res, stats, n), secs = timed(msf, pg)
        rr, basic = int(stats["msgs_rr"]), int(stats["msgs_basic"])
        row(f"fig13.msf.{gname}", secs,
            f"rr={rr};basic={basic};x={basic / max(rr, 1):.2f}"
            f";w={float(res[1]):.1f};rounds={int(n)}")
    return True


if __name__ == "__main__":
    run()

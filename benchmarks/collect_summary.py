"""Merge every ``BENCH_*.json`` gate artifact into one summary file.

CI runs one hard-gated benchmark per perf surface (balance, graph,
pipeline), each writing its own ``BENCH_<name>.json`` artifact.  The
``bench-summary`` job downloads them all and runs this script so the
whole perf trajectory of a commit is a single download:

    python benchmarks/collect_summary.py --root artifacts \
        --out bench-summary.json

Exits non-zero when no report is found (a silently empty summary would
read as "no perf surface regressed" when nothing was measured at all).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def collect(root: str = ".", out: str = "bench-summary.json") -> dict:
    summary: dict = {"reports": {}, "sources": {}}
    for p in sorted(Path(root).rglob("BENCH_*.json")):
        name = p.stem[len("BENCH_"):]
        summary["reports"][name] = json.loads(p.read_text())
        summary["sources"][name] = str(p)
        print(f"[bench-summary] merged {name} <- {p}")
    if not summary["reports"]:
        raise SystemExit(
            f"[bench-summary] no BENCH_*.json found under {root!r} — "
            f"nothing was measured")
    Path(out).write_text(json.dumps(summary, indent=2))
    print(f"[bench-summary] {len(summary['reports'])} report(s) -> {out}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".",
                    help="directory searched recursively for BENCH_*.json "
                         "(the downloaded-artifacts dir in CI)")
    ap.add_argument("--out", default="bench-summary.json")
    args = ap.parse_args()
    collect(root=args.root, out=args.out)


if __name__ == "__main__":
    main()

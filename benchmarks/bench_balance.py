"""Paper Figs. 1-2: per-worker sent-message histograms.

Fig. 1: Hash-Min on the skewed graph, with vs without mirroring — the
uneven blue bars become even short red bars.
Fig. 2: S-V on the road graph, request-respond vs basic.
Prints the full per-worker histograms as CSV for plotting.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import paper_graphs, row, timed
from repro.algorithms.hashmin import hashmin
from repro.algorithms.sv import sv
from repro.core.cost_model import choose_tau
from repro.graph.structs import partition
from repro.train.fault import straggler_report

M = 16


def run(scale=20_000):
    print("# Fig1/2: name,us_per_call,maxmean|cv|hist")
    graphs = paper_graphs(scale)

    g = graphs["btc_like"].symmetrized()
    tau = choose_tau(g.out_degrees(), M)
    per_backend = {}
    for label, tau_i, mirror in [("noM", None, False), ("mirrored", tau, True)]:
        pg = partition(g, M, tau=tau_i, seed=0)
        for backend in ("dense", "pallas"):
            (res, stats, n), secs = timed(hashmin, pg, use_mirroring=mirror,
                                          backend=backend)
            per = np.asarray(stats["per_worker_total"] if mirror
                             else stats["per_worker_combined"])
            per_backend[(label, backend)] = per
            rep = straggler_report(per)
            hist = "|".join(str(int(x)) for x in per)
            row(f"fig1.hashmin.btc_like.{label}.{backend}", secs,
                f"maxmean={rep['max_over_mean']:.2f};"
                f"cv={rep['cv']:.2f};{hist}")
        # the plan backend must not change the balance picture at all
        assert np.array_equal(per_backend[(label, "dense")],
                              per_backend[(label, "pallas")]), label

    g = graphs["usa_like"].symmetrized()
    pg = partition(g, M, tau=None, seed=0)
    (labels, stats, n), secs = timed(sv, pg)
    for label, key in [("basic", "per_worker_basic"), ("reqresp",
                                                       "per_worker_rr")]:
        per = np.asarray(stats[key])
        rep = straggler_report(per)
        hist = "|".join(str(int(x)) for x in per)
        row(f"fig2.sv.usa_like.{label}", secs,
            f"maxmean={rep['max_over_mean']:.2f};cv={rep['cv']:.2f};{hist}")
    return True


if __name__ == "__main__":
    run()

"""Load-balance benchmarks.

Two modes:

* ``run()`` (default CLI) — paper Figs. 1-2: per-worker sent-message
  histograms (Hash-Min with/without mirroring, S-V request-respond vs
  basic), printed as CSV for plotting.
* ``balance_gate()`` (``--gate``) — the partitioner trajectory the CI
  ``bench-balance`` job pins: on the n=200k power-law graph at M=64 it
  partitions with ``balance`` in {hash, edges, edges+refine, split},
  records per-worker / per-physical-shard / per-device edge loads,
  cross-worker / cross-device message fractions
  (``exec.crossness_report``), wall times, and message totals to
  ``BENCH_balance.json``, and **asserts** (hard gates, not advisory):

  - ``balance="split"`` per-worker edge-load max_over_mean <= 1.25
    (the hash baseline on this graph is degree-skew-proportional, ~7x);
  - the locality refinement pass strictly reduces the cross-device
    message fraction vs plain ``edges`` at equal-or-better per-worker
    edge-load max_over_mean (locality must never be bought with
    imbalance — the refiner's load cap, asserted here);
  - algorithm outputs are identical across all modes (canonicalized
    to original-vertex space — the modes only move vertices);
  - ``edges`` and ``split`` agree on every raw message count: splitting
    re-shards combining, it never invents or loses a basic message.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np  # noqa: E402

from benchmarks.common import paper_graphs, row, timed  # noqa: E402
from repro.api import Engine, config_of  # noqa: E402
from repro.algorithms.hashmin import hashmin  # noqa: E402
from repro.algorithms.sv import sv  # noqa: E402
from repro.core.cost_model import (choose_tau, predicted_balance,  # noqa: E402
                                   straggler_report, vertex_cost)
from repro.graph.structs import canonical_labels, partition  # noqa: E402

M = 16

GATE_MAX_OVER_MEAN = 1.25


def run(scale=20_000):
    print("# Fig1/2: name,us_per_call,maxmean|cv|hist")
    graphs = paper_graphs(scale)

    g = graphs["btc_like"].symmetrized()
    tau = choose_tau(g.out_degrees(), M)
    per_backend = {}
    for label, tau_i, mirror in [("noM", None, False), ("mirrored", tau, True)]:
        pg = partition(g, M, tau=tau_i, seed=0)
        for backend in ("dense", "pallas"):
            (res, stats, n), secs = timed(hashmin, pg, use_mirroring=mirror,
                                          backend=backend)
            per = np.asarray(stats["per_worker_total"] if mirror
                             else stats["per_worker_combined"])
            per_backend[(label, backend)] = per
            rep = straggler_report(per)
            hist = "|".join(str(int(x)) for x in per)
            row(f"fig1.hashmin.btc_like.{label}.{backend}", secs,
                f"maxmean={rep['max_over_mean']:.2f};"
                f"cv={rep['cv']:.2f};{hist}")
        # the plan backend must not change the balance picture at all
        assert np.array_equal(per_backend[(label, "dense")],
                              per_backend[(label, "pallas")]), label
    # the edge-balanced partitioner must beat the hash baseline on the
    # skewed graph without changing the component labels
    pg_h = partition(g, M, tau=None, seed=0, layout="csr")
    pg_s = partition(g, M, tau=None, seed=0, layout="csr", balance="split",
                     split_factor=1.1)
    bal_h = straggler_report(pg_h.edge_load())
    bal_s = straggler_report(pg_s.edge_load(phys=True))
    row("fig1.partition.btc_like.hash", 0.0,
        f"maxmean={bal_h['max_over_mean']:.2f}")
    row("fig1.partition.btc_like.split", 0.0,
        f"maxmean={bal_s['max_over_mean']:.2f}")
    assert bal_s["max_over_mean"] <= bal_h["max_over_mean"] + 1e-9

    g = graphs["usa_like"].symmetrized()
    pg = partition(g, M, tau=None, seed=0)
    (labels, stats, n), secs = timed(sv, pg)
    for label, key in [("basic", "per_worker_basic"), ("reqresp",
                                                       "per_worker_rr")]:
        per = np.asarray(stats[key])
        rep = straggler_report(per)
        hist = "|".join(str(int(x)) for x in per)
        row(f"fig2.sv.usa_like.{label}", secs,
            f"maxmean={rep['max_over_mean']:.2f};cv={rep['cv']:.2f};{hist}")
    return True


def balance_gate(n: int = 200_000, workers: int = 64, devices: int = 8,
                 out: str = "BENCH_balance.json",
                 split_factor: float = 1.1) -> dict:
    """The CI load-balance trajectory (hard gate)."""
    from repro.core.exec import crossness_report, device_edge_loads
    from repro.graph import generators as gen

    t0 = time.perf_counter()
    g = gen.powerlaw(n, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    gen_s = time.perf_counter() - t0
    report = {"n": g.n, "m": g.m, "workers": workers, "devices": devices,
              "split_factor": split_factor, "gen_s": round(gen_s, 2),
              "gate_max_over_mean": GATE_MAX_OVER_MEAN, "modes": {}}

    results = {}
    for mode in ("hash", "edges", "edges+refine", "split"):
        t0 = time.perf_counter()
        # tau=None isolates the partitioner: with mirroring on, Ch_mir
        # already spreads the hubs' fan-out (Figs. 1-2); without it the
        # assignment and the split boundaries must carry the skew alone.
        pg = partition(g, workers, tau=None, seed=0, layout="csr",
                       balance=mode, split_factor=split_factor)
        part_s = time.perf_counter() - t0
        loads = pg.edge_load()
        ploads = pg.edge_load(phys=True)
        t0 = time.perf_counter()
        res = Engine(config_of(pg, use_mirroring=False,
                               backend="pallas")).run("hashmin", pg)
        labels, stats, n_ss = res.state, res.stats, res.n_supersteps
        run_s = time.perf_counter() - t0
        cell = {
            "partition_s": round(part_s, 2),
            "hashmin_s": round(run_s, 2),
            "supersteps": int(n_ss),
            "M_phys": int(pg.M_phys),
            "worker_load": straggler_report(loads),
            "phys_load": straggler_report(ploads),
            "device_load": straggler_report(
                device_edge_loads(pg, devices)),
            "msgs_basic": int(stats["msgs_basic"]),
            "msgs_combined": int(stats["msgs_combined"]),
            "msgs_total": int(stats["msgs_total"]),
            "crossness": crossness_report(pg, devices),
        }
        # the cost model's a-priori prediction for this assignment, next
        # to the realized loads it is supposed to anticipate
        assign = np.asarray(pg.perm) // pg.n_loc
        cell["predicted"] = predicted_balance(
            vertex_cost(g.out_degrees(), workers, None), assign, workers)
        report["modes"][mode] = cell
        results[mode] = (pg, np.asarray(labels), stats)
        print(f"[balance] {mode}: partition {part_s:.1f}s, hashmin "
              f"{run_s:.1f}s/{int(n_ss)} ss, M_phys={pg.M_phys}, "
              f"edge-load max/mean={cell['phys_load']['max_over_mean']:.3f}"
              f" (workers {cell['worker_load']['max_over_mean']:.3f}), "
              f"device max/mean="
              f"{cell['device_load']['max_over_mean']:.3f}, "
              f"cross-device frac="
              f"{cell['crossness']['cross_device_frac']:.4f}, "
              f"msgs={cell['msgs_total']:,d}")

    # --- correctness invariants (identical outputs, honest accounting) --
    canon = {m: canonical_labels(pg, lab) for m, (pg, lab, _) in
             results.items()}
    for mode in ("edges", "edges+refine", "split"):
        assert np.array_equal(canon["hash"], canon[mode]), \
            f"{mode} balance changed the components"
    # same assignment => bitwise-identical labels and identical raw counts
    assert np.array_equal(results["edges"][1], results["split"][1]), \
        "splitting changed a label bit"
    assert (report["modes"]["edges"]["msgs_basic"]
            == report["modes"]["split"]["msgs_basic"]), \
        "splitting changed the basic message count"

    # --- the hard gates --------------------------------------------------
    baseline = report["modes"]["hash"]["phys_load"]["max_over_mean"]
    split_mm = report["modes"]["split"]["phys_load"]["max_over_mean"]
    edges_cd = report["modes"]["edges"]["crossness"]["cross_device_frac"]
    ref_cd = report["modes"]["edges+refine"]["crossness"][
        "cross_device_frac"]
    edges_mm = report["modes"]["edges"]["worker_load"]["max_over_mean"]
    ref_mm = report["modes"]["edges+refine"]["worker_load"][
        "max_over_mean"]
    report["gate_ok"] = bool(split_mm <= GATE_MAX_OVER_MEAN)
    report["gate_refine_crossness_ok"] = bool(ref_cd < edges_cd)
    # refinement never buys locality with imbalance: equal-or-better
    # load balance than the assignment it refines (its load cap)
    report["gate_refine_balance_ok"] = bool(ref_mm <= edges_mm + 1e-9)
    print(f"[balance] GATE: hash baseline max/mean={baseline:.3f} -> "
          f"split {split_mm:.3f} (gate <= {GATE_MAX_OVER_MEAN})")
    print(f"[balance] GATE: cross-device fraction edges {edges_cd:.4f} "
          f"-> refined {ref_cd:.4f} (strictly less) at worker max/mean "
          f"{ref_mm:.3f} vs edges {edges_mm:.3f} (equal or better)")
    # report lands on disk BEFORE any gate can abort the job
    Path(out).write_text(json.dumps(report, indent=2))
    print(f"[balance] report -> {out}")
    assert report["gate_ok"], (
        f"balance gate FAILED: split per-worker edge-load max_over_mean "
        f"{split_mm:.3f} > {GATE_MAX_OVER_MEAN}")
    assert report["gate_refine_crossness_ok"], (
        f"refine gate FAILED: refined cross-device fraction {ref_cd:.4f} "
        f"not < unrefined {edges_cd:.4f}")
    assert report["gate_refine_balance_ok"], (
        f"refine gate FAILED: refined worker edge-load max_over_mean "
        f"{ref_mm:.3f} > edges {edges_mm:.3f} (locality bought with "
        f"imbalance)")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="run the CI load-balance gate instead of the "
                         "Fig. 1/2 histograms")
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--workers", type=int, default=64)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--split-factor", type=float, default=1.1)
    ap.add_argument("--out", default="BENCH_balance.json")
    args = ap.parse_args()
    if args.gate:
        balance_gate(n=args.n, workers=args.workers, devices=args.devices,
                     out=args.out, split_factor=args.split_factor)
    else:
        run()


if __name__ == "__main__":
    main()

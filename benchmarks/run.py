# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--smoke`` runs a fast invariant-checking mode for CI: it asserts the
# paper's message-count theorems and dense/pallas backend parity on small
# graphs and writes the numbers to a JSON artifact.
import argparse
import json
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def smoke(out_path: str, scale: int = 4000, M: int = 8) -> None:
    import numpy as np
    import jax.numpy as jnp
    from repro.algorithms.hashmin import hashmin
    from repro.algorithms.sv import sv
    from repro.core.cost_model import choose_tau, thm1_bound
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    report = {"scale": scale, "workers": M, "checks": {}}

    def check(name, ok, **numbers):
        report["checks"][name] = {"ok": bool(ok),
                                  **{k: int(v) for k, v in numbers.items()}}
        status = "ok" if ok else "FAIL"
        print(f"[smoke] {name}: {status} "
              + " ".join(f"{k}={int(v):,d}" for k, v in numbers.items()))
        assert ok, name

    g = gen.powerlaw(scale, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    tau = choose_tau(g.out_degrees(), M)
    pg = partition(g, M, tau=tau, seed=0)
    deg = np.asarray(pg.deg)

    stats = {}
    n_ss = 0
    for backend in ("dense", "pallas"):
        _, stats[backend], n_ss = hashmin(pg, backend=backend)

    s = stats["dense"]
    # combining only ever removes messages
    check("combined_le_basic",
          int(s["msgs_combined"]) <= int(s["msgs_basic"]),
          combined=s["msgs_combined"], basic=s["msgs_basic"])
    # Theorem 1: each mirrored broadcast costs <= min(M, d(v)) messages;
    # summed over active mirrored vertices and supersteps it is bounded by
    # supersteps * sum over mirrored v of min(M, d(v))
    nmir = int((np.asarray(pg.mir_ids) < pg.n_pad).sum())
    per_v_bound = sum(thm1_bound(M, int(d))
                      for d in deg.reshape(-1)[np.asarray(pg.mir_ids)[:nmir]])
    check("thm1_mirror_bound",
          int(s["msgs_mirror"]) <= int(n_ss) * per_v_bound,
          mirror=s["msgs_mirror"], bound=int(n_ss) * per_v_bound)
    # mirroring beats pure combining on the skewed graph (Fig. 12 effect)
    _, s_nom, _ = hashmin(pg, use_mirroring=False)
    check("mirroring_reduces_total",
          int(s["msgs_total"]) <= int(s_nom["msgs_combined"]),
          mirrored=s["msgs_total"], no_mirroring=s_nom["msgs_combined"])
    # backend parity: the pallas plan path must not change a single count
    parity = all(
        np.array_equal(np.asarray(stats["dense"][k]),
                       np.asarray(stats["pallas"][k]))
        for k in stats["dense"])
    check("backend_parity", parity,
          dense_total=stats["dense"]["msgs_total"],
          pallas_total=stats["pallas"]["msgs_total"])
    # layout parity: the flat csr representation must not change a count
    pg_csr = partition(g, M, tau=tau, seed=0, layout="csr")
    _, s_csr, _ = hashmin(pg_csr, backend="pallas")
    layout_parity = all(
        np.array_equal(np.asarray(stats["dense"][k]), np.asarray(s_csr[k]))
        for k in stats["dense"])
    check("layout_parity", layout_parity,
          padded_total=stats["dense"]["msgs_total"],
          csr_total=s_csr["msgs_total"])
    # Theorem 3: request-respond never exceeds basic in S-V
    pg_sv = partition(g, M, tau=None, seed=0)
    _, s_sv, _ = sv(pg_sv, backend="pallas")
    check("thm3_rr_le_basic", int(s_sv["msgs_rr"]) <= int(s_sv["msgs_basic"]),
          rr=s_sv["msgs_rr"], basic=s_sv["msgs_basic"])

    Path(out_path).write_text(json.dumps(report, indent=2))
    print(f"[smoke] all invariants hold; report -> {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: assert the paper's message-count "
                         "invariants + backend parity, emit JSON")
    ap.add_argument("--out", default="bench-smoke.json",
                    help="JSON report path (smoke mode)")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.out)
        return

    from benchmarks import (bench_balance, bench_kernels, bench_mirroring,
                            bench_reqresp, bench_roofline)
    suites = [
        ("fig12_mirroring", bench_mirroring.run),
        ("fig13_reqresp", bench_reqresp.run),
        ("fig1_2_balance", bench_balance.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failures = []
    for name, fn in suites:
        print(f"\n### {name}")
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        raise SystemExit(1)
    print("\nALL BENCHMARK SUITES PASSED")


if __name__ == '__main__':
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--smoke`` runs a fast invariant-checking mode for CI: it asserts the
# paper's message-count theorems, dense/pallas backend parity, and sharded
# executor parity on small graphs and writes the numbers to a JSON
# artifact.  ``--graph-bench`` records the perf trajectory (wall time +
# message counts for every backend x layout x device-count cell) to
# BENCH_graph.json.
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# jax-free: safe to import before the flags are set
from repro.launch.xla_flags import force_host_devices  # noqa: E402


def smoke(out_path: str, scale: int = 4000, M: int = 8) -> None:
    import numpy as np
    import jax.numpy as jnp
    from repro.algorithms.hashmin import hashmin
    from repro.algorithms.sv import sv
    from repro.core.cost_model import choose_tau, thm1_bound
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    report = {"scale": scale, "workers": M, "checks": {}}

    def check(name, ok, **numbers):
        report["checks"][name] = {"ok": bool(ok),
                                  **{k: int(v) for k, v in numbers.items()}}
        status = "ok" if ok else "FAIL"
        print(f"[smoke] {name}: {status} "
              + " ".join(f"{k}={int(v):,d}" for k, v in numbers.items()))
        assert ok, name

    g = gen.powerlaw(scale, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    tau = choose_tau(g.out_degrees(), M)
    pg = partition(g, M, tau=tau, seed=0)
    deg = np.asarray(pg.deg)

    stats = {}
    n_ss = 0
    for backend in ("dense", "pallas"):
        _, stats[backend], n_ss = hashmin(pg, backend=backend)

    s = stats["dense"]
    # combining only ever removes messages
    check("combined_le_basic",
          int(s["msgs_combined"]) <= int(s["msgs_basic"]),
          combined=s["msgs_combined"], basic=s["msgs_basic"])
    # Theorem 1: each mirrored broadcast costs <= min(M, d(v)) messages;
    # summed over active mirrored vertices and supersteps it is bounded by
    # supersteps * sum over mirrored v of min(M, d(v))
    nmir = int((np.asarray(pg.mir_ids) < pg.n_pad).sum())
    per_v_bound = sum(thm1_bound(M, int(d))
                      for d in deg.reshape(-1)[np.asarray(pg.mir_ids)[:nmir]])
    check("thm1_mirror_bound",
          int(s["msgs_mirror"]) <= int(n_ss) * per_v_bound,
          mirror=s["msgs_mirror"], bound=int(n_ss) * per_v_bound)
    # mirroring beats pure combining on the skewed graph (Fig. 12 effect)
    _, s_nom, _ = hashmin(pg, use_mirroring=False)
    check("mirroring_reduces_total",
          int(s["msgs_total"]) <= int(s_nom["msgs_combined"]),
          mirrored=s["msgs_total"], no_mirroring=s_nom["msgs_combined"])
    # backend parity: the pallas plan path must not change a single count
    parity = all(
        np.array_equal(np.asarray(stats["dense"][k]),
                       np.asarray(stats["pallas"][k]))
        for k in stats["dense"])
    check("backend_parity", parity,
          dense_total=stats["dense"]["msgs_total"],
          pallas_total=stats["pallas"]["msgs_total"])
    # layout parity: the flat csr representation must not change a count
    pg_csr = partition(g, M, tau=tau, seed=0, layout="csr")
    _, s_csr, _ = hashmin(pg_csr, backend="pallas")
    layout_parity = all(
        np.array_equal(np.asarray(stats["dense"][k]), np.asarray(s_csr[k]))
        for k in stats["dense"])
    check("layout_parity", layout_parity,
          padded_total=stats["dense"]["msgs_total"],
          csr_total=s_csr["msgs_total"])
    # Theorem 3: request-respond never exceeds basic in S-V
    pg_sv = partition(g, M, tau=None, seed=0)
    _, s_sv, _ = sv(pg_sv, backend="pallas")
    check("thm3_rr_le_basic", int(s_sv["msgs_rr"]) <= int(s_sv["msgs_basic"]),
          rr=s_sv["msgs_rr"], basic=s_sv["msgs_basic"])

    # sharded executor parity: the worker mesh must not change a label or
    # a single message count (dense all_to_all join, 8 forced host devices)
    labels_1, _, _ = hashmin(pg_csr, backend="dense")
    labels_8, s_sh, _ = hashmin(pg_csr, backend="dense", devices=8)
    sharded_parity = (np.array_equal(np.asarray(labels_1),
                                     np.asarray(labels_8))
                      and all(np.array_equal(np.asarray(stats["dense"][k]),
                                             np.asarray(s_sh[k]))
                              for k in stats["dense"]))
    check("sharded_parity", sharded_parity,
          devices1_total=stats["dense"]["msgs_total"],
          devices8_total=s_sh["msgs_total"])

    Path(out_path).write_text(json.dumps(report, indent=2))
    print(f"[smoke] all invariants hold; report -> {out_path}")


def graph_bench(out_path: str, n: int = 200_000, M: int = 8,
                device_counts=(1, 8, (2, 4))) -> None:
    """Perf-trajectory artifact: wall time + message counts for every
    algo x backend x layout x device-count cell — D=8 both as the flat
    1-D mesh and as the hierarchical 2x4 (host, device) mesh — plus the
    per-device compiled-buffer stats of every sharded channel family at
    D=8, and two HARD gates: (a) no sharded channel may
    all-reduce/all-gather an operand of >= n_pad elements (a replicated
    global buffer would void the paper's per-worker communication
    bounds); (b) the cross-host wire volume of the hierarchical static
    exchanges must stay strictly below the flat 1-D all-pairs volume —
    the per-level combine must actually remove traffic from the
    expensive axis.  Wall times include the per-call jit compile (each
    cell builds a fresh step closure) — they are trend numbers, not
    steady-state throughput."""
    from repro.algorithms.hashmin import hashmin
    from repro.algorithms.pagerank import pagerank
    from repro.core.cost_model import choose_tau
    from repro.core.exec import broadcast_plan_kinds
    from repro.core.exec import exchange_volume_report
    from repro.graph import generators as gen
    from repro.graph.structs import partition
    from repro.launch.shard_check import routed_memory_report

    g = gen.powerlaw(n, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    tau = choose_tau(g.out_degrees(), M)
    report = {"n": g.n, "m": g.m, "workers": M, "tau": int(tau),
              "cells": [], "memory": {}, "exchange_volume": {}}
    for layout in ("padded", "csr"):
        pg = partition(g, M, tau=tau, seed=0, layout=layout)
        # per-device peak live-buffer bytes + collective operand sizes of
        # the compiled sharded channels (the routed-exchange artifact)
        flat_counts = [d for d in device_counts if not isinstance(d, tuple)]
        mem = routed_memory_report(pg, devices=max(flat_counts))
        report["memory"][layout] = mem
        n_pad = pg.n_pad
        for prog, entry in mem["programs"].items():
            worst = entry["collective_max_elems"]
            bad = max(worst["all-reduce"], worst["all-gather"])
            print(f"[graph-bench] memory {layout}/{prog}: "
                  f"worst replicated collective operand {bad:,d} elems, "
                  f"temp {entry.get('temp_bytes', -1):,d} B")
            assert bad < n_pad, (
                f"{layout}/{prog}: replicated collective operand of "
                f"{bad} elems >= n_pad {n_pad} — a sharded channel is "
                f"replicating global state again")
        if layout == "csr":
            # static wire-lane accounting of the per-superstep exchanges
            # (plan legs + fetch plans, pallas kinds): the flat D=8 mesh
            # treats every device pair alike; on the 2-D meshes only the
            # post-combine residue crosses the host axis
            kinds = broadcast_plan_kinds("pallas")
            vols = {"8": exchange_volume_report(pg, 8, kinds),
                    "2x4": exchange_volume_report(pg, (2, 4), kinds),
                    "4x2": exchange_volume_report(pg, (4, 2), kinds)}
            report["exchange_volume"] = vols
            flat_total = vols["8"]["total"]
            for tag in ("2x4", "4x2"):
                cross = vols[tag]["cross_host"]
                print(f"[graph-bench] exchange-volume {tag}: "
                      f"cross_host={cross:,d} intra_host="
                      f"{vols[tag]['intra_host']:,d} vs flat all-pairs "
                      f"{flat_total:,d} lanes")
                assert cross < flat_total, (
                    f"{tag}: cross-host volume {cross} >= flat all-pairs "
                    f"volume {flat_total} — the per-level combine is not "
                    f"removing traffic from the host axis")
        for backend in ("dense", "pallas"):
            for algo, fn in (("hashmin", hashmin),
                             ("pagerank", lambda p, **kw: pagerank(
                                 p, n_iters=10, tol=0.0, **kw))):
                for D in device_counts:
                    dev = None if D == 1 else D
                    tag = ("x".join(str(d) for d in D)
                           if isinstance(D, tuple) else D)
                    t0 = time.perf_counter()
                    _, stats, n_ss = fn(pg, backend=backend, devices=dev)
                    wall = time.perf_counter() - t0
                    cell = {"algo": algo, "backend": backend,
                            "layout": layout, "devices": tag,
                            "wall_s": round(wall, 3),
                            "supersteps": int(n_ss),
                            "msgs_total": int(stats["msgs_total"]),
                            "msgs_basic": int(stats["msgs_basic"])}
                    report["cells"].append(cell)
                    print(f"[graph-bench] {algo}/{layout}/{backend}/"
                          f"devices={tag}: {wall:.2f}s "
                          f"msgs={cell['msgs_total']:,d}")
    # the mesh is a representation choice: message counts must agree
    # across every cell of one algo
    for algo in ("hashmin", "pagerank"):
        totals = {c["msgs_total"] for c in report["cells"]
                  if c["algo"] == algo}
        assert len(totals) == 1, f"{algo}: msgs_total diverged {totals}"
    Path(out_path).write_text(json.dumps(report, indent=2))
    print(f"[graph-bench] report -> {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: assert the paper's message-count "
                         "invariants + backend/layout/sharded parity, "
                         "emit JSON")
    ap.add_argument("--graph-bench", action="store_true",
                    help="record wall time + message counts for every "
                         "backend x layout x device-count cell")
    ap.add_argument("--n", type=int, default=200_000,
                    help="graph size (graph-bench mode)")
    ap.add_argument("--out", default="bench-smoke.json",
                    help="JSON report path (smoke / graph-bench mode)")
    args = ap.parse_args()
    if args.smoke or args.graph_bench:
        force_host_devices(8)      # before the first jax import
    if args.smoke:
        smoke(args.out)
        return
    if args.graph_bench:
        graph_bench(args.out, n=args.n)
        return

    from benchmarks import (bench_balance, bench_kernels, bench_mirroring,
                            bench_reqresp, bench_roofline)
    suites = [
        ("fig12_mirroring", bench_mirroring.run),
        ("fig13_reqresp", bench_reqresp.run),
        ("fig1_2_balance", bench_balance.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failures = []
    for name, fn in suites:
        print(f"\n### {name}")
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        raise SystemExit(1)
    print("\nALL BENCHMARK SUITES PASSED")


if __name__ == '__main__':
    main()

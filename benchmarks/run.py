# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    from benchmarks import (bench_balance, bench_kernels, bench_mirroring,
                            bench_reqresp, bench_roofline)
    suites = [
        ("fig12_mirroring", bench_mirroring.run),
        ("fig13_reqresp", bench_reqresp.run),
        ("fig1_2_balance", bench_balance.run),
        ("kernels", bench_kernels.run),
        ("roofline", bench_roofline.run),
    ]
    failures = []
    for name, fn in suites:
        print(f"\n### {name}")
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        raise SystemExit(1)
    print("\nALL BENCHMARK SUITES PASSED")


if __name__ == '__main__':
    main()

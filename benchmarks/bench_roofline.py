"""Roofline report: renders the table in EXPERIMENTS.md §Roofline from the
dry-run artifacts (artifacts/dryrun/*.json)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_artifacts(mesh_filter=None, tag=""):
    arts = []
    for p in sorted(ART.glob("*.json")):
        a = json.loads(p.read_text())
        if mesh_filter and a.get("mesh") != mesh_filter:
            continue
        if (a.get("options", {}).get("tag") or "") != tag:
            continue
        arts.append(a)
    return arts


def run():
    print("# roofline: name,us_per_call(bound term),dominant|terms|frac")
    arts = load_artifacts(mesh_filter="16x16", tag="")
    if not arts:
        print("roofline.NO_ARTIFACTS,0,run launch/dryrun first")
        return False
    n_ok = n_skip = 0
    for a in arts:
        name = f"roofline.{a['arch']}.{a['shape']}"
        if a["status"] == "skipped":
            n_skip += 1
            row(name, 0.0, f"SKIP:{a['reason'][:60]}")
            continue
        if a["status"] != "ok":
            row(name, 0.0, "ERROR")
            continue
        n_ok += 1
        r = a["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        row(name, bound,
            f"dom={r['dominant']};c={r['compute_s']:.3e}"
            f";m={r['memory_s']:.3e};coll={r['collective_s']:.3e}"
            f";useful={r['useful_ratio']:.3f}"
            f";frac={r['roofline_fraction']:.4f}")
    print(f"# roofline summary: ok={n_ok} skipped={n_skip}")
    return n_ok > 0


if __name__ == "__main__":
    run()

"""Peak-RSS measurement of ``partition()`` under each edge layout.

The padded layout pays O(M * E_hot): one hot worker pads every row, so at
n=1e6 / M=256 the host arrays blow past 4 GB before any channel runs.
The csr layout is O(E + M + n).  ``ru_maxrss`` is a process-wide
high-water mark, so the parent spawns one subprocess per layout and
merges the children's JSON lines into one report (the CI artifact).

    python benchmarks/mem_partition.py --n 1000000 --workers 256 \
        --out partition-rss.json
"""
import argparse
import json
import resource
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"


def _rss_mb() -> float:
    # ru_maxrss is KiB on Linux, bytes on macOS
    div = 2 ** 20 if sys.platform == "darwin" else 1024.0
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / div


def child(layout: str, n: int, M: int, avg_deg: int, seed: int) -> None:
    sys.path.insert(0, str(SRC))
    import numpy as np
    from repro.core.cost_model import choose_tau
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(n, avg_deg=avg_deg, seed=seed).symmetrized()
    rss_graph = _rss_mb()
    tau = choose_tau(g.out_degrees(), M)
    pg = partition(g, M, tau=tau, seed=seed, layout=layout)
    rss_peak = _rss_mb()

    edge_fields = ("eg_src", "eg_dst", "eg_mask", "eg_w",
                   "all_src", "all_dst", "all_mask", "all_w",
                   "mir_esrc", "mir_edst", "mir_emask", "mir_ew")
    array_mb = sum(np.asarray(getattr(pg, f)).nbytes
                   for f in edge_fields) / 2 ** 20
    print(json.dumps({
        "layout": layout, "n": n, "workers": M, "edges": int(g.m),
        "tau": int(tau),
        "rss_after_graph_mb": round(rss_graph, 1),
        "rss_peak_mb": round(rss_peak, 1),
        "partition_rss_mb": round(rss_peak - rss_graph, 1),
        "edge_array_mb": round(array_mb, 1),
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--workers", type=int, default=256)
    ap.add_argument("--avg-deg", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layouts", default="csr,padded")
    ap.add_argument("--out", default="partition-rss.json")
    ap.add_argument("--child", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        child(args.child, args.n, args.workers, args.avg_deg, args.seed)
        return

    results = []
    for layout in args.layouts.split(","):
        cmd = [sys.executable, __file__, "--child", layout,
               "--n", str(args.n), "--workers", str(args.workers),
               "--avg-deg", str(args.avg_deg), "--seed", str(args.seed)]
        out = subprocess.run(cmd, capture_output=True, text=True, check=True)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
        results.append(rec)
        print(f"[mem] {layout:7s} partition peak "
              f"{rec['partition_rss_mb']:>9.1f} MB "
              f"(edge arrays {rec['edge_array_mb']:.1f} MB)")
    report = {"n": args.n, "workers": args.workers,
              "avg_deg": args.avg_deg, "layouts": results}
    if len(results) == 2:
        a, b = sorted(results, key=lambda r: r["partition_rss_mb"])
        if a["partition_rss_mb"] > 0:
            report["ratio"] = round(
                b["partition_rss_mb"] / a["partition_rss_mb"], 2)
            print(f"[mem] {b['layout']} / {a['layout']} = "
                  f"{report['ratio']}x")
    Path(args.out).write_text(json.dumps(report, indent=2))
    print(f"[mem] report -> {args.out}")


if __name__ == "__main__":
    main()

"""Supersteps/sec for the double-buffered exchange pipeline (PR 6).

Measures the steady-state superstep rate of a fixed-iteration PageRank
(the paper's broadcast/sum workload) on the csr/pallas **sharded**
executor, for devices {1, 8} x pipeline {off, on}, and writes the
figures to ``BENCH_pipeline.json``.  ``--gate`` additionally **asserts**
(hard gate, not a report) that the pipelined path sustains at least
``GATE_MIN_RATIO - GATE_NOISE`` x the sequential supersteps/sec at
every device count: the pipeline must never cost real throughput, and
the threshold is ratcheted as overlap wins land.

Methodology: each (devices, pipeline) cell builds its jitted program
ONCE via ``exec.build_sharded`` and re-invokes the already-compiled
function for every timed sample — per-call re-tracing is what makes
naive wall-clock deltas jitter by 2-3x (the jit compile at n=1M runs
minutes and varies tens of seconds run to run, drowning a 12-superstep
signal).  Timed samples for the sequential and pipelined programs of
one device count are interleaved, so a co-tenant landing on the runner
mid-measurement degrades both paths instead of poisoning one; the best
sample per program is kept.  The step never halts early (``tol`` is
effectively 0), so every sample runs exactly ``--iters`` supersteps.

    python benchmarks/bench_pipeline.py                  # report mode
    python benchmarks/bench_pipeline.py --gate           # CI hard gate
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# jax-free: safe to import before the device flags are set
from repro.launch.xla_flags import force_host_devices  # noqa: E402

# Pipelined supersteps/sec must be >= (GATE_MIN_RATIO - GATE_NOISE) x
# sequential.  On a single CPU host XLA runs the collectives
# synchronously, so the honest expectation is parity minus the copy
# cost of carrying one in-flight exchange through the round loop; the
# ratio gets ratcheted above 1.0 once an async-collective backend
# records a real overlap win.
GATE_MIN_RATIO = 1.0
GATE_NOISE = 0.15


def _build(pg, devices: int, pipeline: bool, n_iters: int,
           damping: float = 0.85):
    """The paper's PageRank broadcast step (cf. algorithms/pagerank),
    fixed iteration count (never halts early), compiled once through
    exec.build_sharded so timed samples rerun the same executable."""
    import jax.numpy as jnp
    from repro.core import exec as exec_mod
    from repro.core.channels import broadcast

    n = pg.n

    def make_step(g):
        deg = jnp.maximum(g.deg, 1)

        def step(state, i):
            pr = state
            contrib = jnp.where(g.vmask, pr / deg, 0.0)
            active = g.vmask & (g.deg > 0)
            inbox, stats = broadcast(g, contrib, active, op="sum",
                                     use_mirroring=True, backend="pallas")
            new_pr = jnp.where(g.vmask,
                               (1 - damping) / n + damping * inbox, 0.0)
            return new_pr, jnp.zeros((), bool), stats
        return step

    pr0 = jnp.where(pg.vmask, 1.0 / n, 0.0)
    fn, args, _ = exec_mod.build_sharded(
        pg, make_step, pr0, n_iters, devices=devices,
        plan_kinds=exec_mod.broadcast_plan_kinds("pallas", True),
        pipeline=pipeline)
    return fn, args


def _measure_device(pg, devices: int, n_iters: int, repeat: int):
    """One devices= cell: compile both programs, then interleave timed
    invocations of the compiled executables."""
    import jax

    progs, compile_s = {}, {}
    for pipe in (False, True):
        fn, args = _build(pg, devices, pipe, n_iters)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        compile_s[pipe] = time.perf_counter() - t0
        progs[pipe] = (fn, args, int(out[2]))

    best = {False: float("inf"), True: float("inf")}
    for _ in range(repeat):
        for pipe in (False, True):
            fn, args, _ = progs[pipe]
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[pipe] = min(best[pipe], time.perf_counter() - t0)

    cells = []
    for pipe in (False, True):
        n_ss = progs[pipe][2]
        assert n_ss == n_iters, (n_ss, n_iters)
        per_ss = best[pipe] / n_ss
        cells.append({"devices": devices, "pipeline": pipe,
                      "supersteps_per_sec": round(1.0 / per_ss, 3),
                      "sec_per_superstep": round(per_ss, 4),
                      "wall_s": round(best[pipe], 3),
                      "compile_and_first_run_s": round(compile_s[pipe], 3),
                      "supersteps": n_ss})
    return cells


def pipeline_bench(n: int = 1_000_000, workers: int = 32,
                   device_counts=(1, 8), n_iters: int = 12,
                   repeat: int = 2, out: str = "BENCH_pipeline.json",
                   gate: bool = False) -> dict:
    from repro.core.cost_model import choose_tau
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(n, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    tau = choose_tau(g.out_degrees(), workers)
    pg = partition(g, workers, tau=tau, seed=0, layout="csr")
    report = {"n": g.n, "m": g.m, "workers": workers, "tau": int(tau),
              "layout": "csr", "backend": "pallas", "algo": "pagerank",
              "n_iters": n_iters, "gate_min_ratio": GATE_MIN_RATIO,
              "gate_noise": GATE_NOISE, "cells": [], "ratios": {}}

    for D in device_counts:
        seq, pipe = _measure_device(pg, D, n_iters, repeat)
        report["cells"] += [seq, pipe]
        ratio = pipe["supersteps_per_sec"] / seq["supersteps_per_sec"]
        report["ratios"][str(D)] = round(ratio, 3)
        print(f"[pipeline-bench] devices={D}: sequential "
              f"{seq['supersteps_per_sec']:.2f} ss/s, pipelined "
              f"{pipe['supersteps_per_sec']:.2f} ss/s "
              f"(ratio {ratio:.3f})", flush=True)

    # write BEFORE asserting: the JSON is the diagnostic when the gate
    # fails
    Path(out).write_text(json.dumps(report, indent=2))
    print(f"[pipeline-bench] report -> {out}")
    if gate:
        floor = GATE_MIN_RATIO - GATE_NOISE
        for D, ratio in report["ratios"].items():
            assert ratio >= floor, (
                f"devices={D}: pipelined supersteps/sec fell to "
                f"{ratio:.3f}x sequential (< {floor:.2f}) — the double "
                f"buffer is costing throughput")
        print(f"[pipeline-bench] GATE OK: pipelined >= {floor:.2f}x "
              f"sequential at every device count")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="hard-fail if pipelined supersteps/sec drops "
                         "below the gate ratio at any device count")
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--iters", type=int, default=12)
    ap.add_argument("--repeat", type=int, default=2)
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()
    force_host_devices(max(args.devices))   # before the first jax import
    pipeline_bench(n=args.n, workers=args.workers,
                   device_counts=tuple(args.devices), n_iters=args.iters,
                   repeat=args.repeat, out=args.out, gate=args.gate)


if __name__ == "__main__":
    main()

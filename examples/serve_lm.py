"""Serve a small model with batched requests: prefill + KV/SSM-cache decode
across three architecture families (dense GQA, MoE, SSM).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.serve import run

for arch in ["tinyllama_1_1b", "olmoe_1b_7b", "mamba2_1_3b"]:
    run(arch, reduced=True, batch=4, prompt_len=32, gen=16)
print("\nAll three families served. Done.")

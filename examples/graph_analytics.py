"""End-to-end graph-analytics driver over all paper workloads: the
paper-kind production scenario (CC + MSF + PageRank + SSSP on one graph
corpus, with channel configuration and balance reporting) — everything
through the ``repro.api.Engine`` front door.

    PYTHONPATH=src python examples/graph_analytics.py [scale]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.api import Engine
from repro.core.cost_model import choose_tau
from repro.graph import generators as gen
from repro.core.cost_model import straggler_report

scale = int(sys.argv[1]) if len(sys.argv) > 1 else 10_000
M = 16

g = gen.powerlaw(scale, avg_deg=8, alpha=1.8, seed=0,
                 weighted=True).symmetrized()
tau = choose_tau(g.out_degrees(), M)
eng = Engine()                     # dense backend, padded layout, 1 device
pg = eng.partition(g, M, tau=tau, seed=0)
print(f"corpus: n={g.n} m={g.m} tau*={tau} M={M}")

print("\n-- connected components (Hash-Min, mirrored) --")
res = eng.run("hashmin", pg)
rep = straggler_report(np.asarray(res.stats["per_worker_total"]))
print(f"supersteps={res.n_supersteps} "
      f"msgs={int(res.stats['msgs_total']):,} "
      f"balance max/mean={rep['max_over_mean']:.2f}")

print("\n-- connected components (S-V, request-respond) --")
res = eng.run("sv", pg)
rr, basic = int(res.stats["msgs_rr"]), int(res.stats["msgs_basic"])
print(f"rounds={res.n_supersteps} rr={rr:,} basic={basic:,} "
      f"({basic / max(rr, 1):.2f}x reduction)")

print("\n-- PageRank (10 iters) --")
res = eng.run("pagerank", pg, n_iters=10, tol=0.0)
pr = np.asarray(res.state).reshape(-1)
top = np.argsort(-pr)[:5]
print(f"msgs={int(res.stats['msgs_total']):,} top-5 pr={pr[top]}")

print("\n-- SSSP from vertex 0 (relay() on mirrors) --")
res = eng.run("sssp", pg, source=int(pg.perm[0]))
d = np.asarray(res.state).reshape(-1)
print(f"supersteps={res.n_supersteps} "
      f"msgs={int(res.stats['msgs_total']):,} "
      f"reached={int(np.isfinite(d).sum())}/{pg.n_pad}")

print("\n-- minimum spanning forest (Boruvka + SEAS) --")
res = eng.run("msf", pg)
labels, total_w, n_edges = res.state
print(f"rounds={res.n_supersteps} |MSF|={int(n_edges)} "
      f"weight={float(total_w):.1f} "
      f"rr={int(res.stats['msgs_rr']):,} "
      f"basic={int(res.stats['msgs_basic']):,}")
print("\nDone.")

"""End-to-end graph-analytics driver over all paper workloads: the
paper-kind production scenario (CC + MSF + PageRank + SSSP on one graph
corpus, with channel configuration and balance reporting).

    PYTHONPATH=src python examples/graph_analytics.py [scale]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.algorithms.hashmin import hashmin
from repro.algorithms.msf import msf
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.sv import sv
from repro.core.cost_model import choose_tau
from repro.graph import generators as gen
from repro.graph.structs import partition
from repro.core.cost_model import straggler_report

scale = int(sys.argv[1]) if len(sys.argv) > 1 else 10_000
M = 16

g = gen.powerlaw(scale, avg_deg=8, alpha=1.8, seed=0,
                 weighted=True).symmetrized()
tau = choose_tau(g.out_degrees(), M)
pg = partition(g, M, tau=tau, seed=0)
print(f"corpus: n={g.n} m={g.m} tau*={tau} M={M}")

print("\n-- connected components (Hash-Min, mirrored) --")
labels, s, n = hashmin(pg)
rep = straggler_report(np.asarray(s["per_worker_total"]))
print(f"supersteps={int(n)} msgs={int(s['msgs_total']):,} "
      f"balance max/mean={rep['max_over_mean']:.2f}")

print("\n-- connected components (S-V, request-respond) --")
labels2, s2, n2 = sv(pg)
print(f"rounds={int(n2)} rr={int(s2['msgs_rr']):,} "
      f"basic={int(s2['msgs_basic']):,} "
      f"({int(s2['msgs_basic']) / max(int(s2['msgs_rr']), 1):.2f}x reduction)")

print("\n-- PageRank (10 iters) --")
pr, s3, _ = pagerank(pg, n_iters=10, tol=0.0)
top = np.argsort(-np.asarray(pr).reshape(-1))[:5]
print(f"msgs={int(s3['msgs_total']):,} top-5 pr={np.asarray(pr).reshape(-1)[top]}")

print("\n-- SSSP from vertex 0 (relay() on mirrors) --")
dist, s4, n4 = sssp(pg, int(pg.perm[0]))
d = np.asarray(dist).reshape(-1)
print(f"supersteps={int(n4)} msgs={int(s4['msgs_total']):,} "
      f"reached={int(np.isfinite(d).sum())}/{pg.n_pad}")

print("\n-- minimum spanning forest (Boruvka + SEAS) --")
(resm, s5, n5) = msf(pg)
print(f"rounds={int(n5)} |MSF|={int(resm[2])} weight={float(resm[1]):.1f} "
      f"rr={int(s5['msgs_rr']):,} basic={int(s5['msgs_basic']):,}")
print("\nDone.")

"""Quickstart: the paper's two techniques in 40 lines.

    PYTHONPATH=src python examples/quickstart.py [scale]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.algorithms.hashmin import hashmin
from repro.algorithms.sv import sv
from repro.core.cost_model import choose_tau, mirror_threshold
from repro.graph import generators as gen
from repro.graph.structs import partition

# A skewed graph: a few vertices have enormous degree (BTC/Twitter-like).
scale = int(sys.argv[1]) if len(sys.argv) > 1 else 20_000
g = gen.powerlaw(scale, avg_deg=8, alpha=1.8, seed=0).symmetrized()
M = 16
deg = g.out_degrees()
tau = choose_tau(deg, M)
print(f"graph: n={g.n} m={g.m} max_deg={deg.max()} avg_deg={deg.mean():.1f}")
print(f"Theorem-2 mirroring threshold: tau* = M*exp(deg_avg/M) = {tau}")

# --- Technique 1: mirroring (high-degree vertices) -----------------------
pg = partition(g, M, tau=tau, seed=0)
labels, stats, n = hashmin(pg, use_mirroring=True)
_, stats_nom, _ = hashmin(pg, use_mirroring=False)
print(f"\nHash-Min CC in {int(n)} supersteps")
print(f"  messages, Pregel basic (no combiner): {int(stats_nom['msgs_basic']):>12,}")
print(f"  messages, with combiner (Pregel-noM): {int(stats_nom['msgs_combined']):>12,}")
print(f"  messages, combiner + mirroring:       {int(stats['msgs_total']):>12,}")

# --- Technique 2: request-respond (algorithm-logic bottlenecks) ----------
labels2, stats2, rounds = sv(pg)
print(f"\nS-V CC in {int(rounds)} rounds (O(log n), pointer jumping)")
print(f"  messages, Pregel basic:    {int(stats2['msgs_basic']):>12,}")
print(f"  messages, request-respond: {int(stats2['msgs_rr']):>12,}")
per = np.asarray(stats2["per_worker_basic"])
per_rr = np.asarray(stats2["per_worker_rr"])
print(f"  worker balance (max/mean): basic {per.max() / per.mean():.2f} "
      f"-> rr {per_rr.max() / per_rr.mean():.2f}")
assert (np.asarray(labels) == np.asarray(labels2)).all(), "CC labels agree"
print("\nHash-Min and S-V agree on all component labels. Done.")

"""Train a ~large-M-param reduced LM for a few hundred steps on CPU with
checkpointing + restart (the LM-side end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py [steps]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch.train import run

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
losses = run("tinyllama_1_1b", reduced=True, steps=steps, batch=8, seq=128,
             ckpt_dir="/tmp/repro_train_lm", ckpt_every=50, lr=1e-3)
print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over {steps} steps")
assert losses[-1] < losses[0], "training must reduce loss"

"""The pluggable partitioner layer (PR 10).

* protocol/registry: every ``balance`` mode resolves to a
  :class:`~repro.graph.partitioner.Partitioner`, and ``partition()``
  consumes all of them through the one ``assign()`` seam;
* locality refinement: strictly descends the weighted ``pair_counts``
  crossness objective under the ``greedy_assign`` slot/load caps
  (equal-or-better balance by construction), with cross-host lanes
  priced above cross-device ones when ``hosts`` is set;
* vertex-cut: a mega-hub whose degree exceeds the split threshold gets
  its state rows force-mirrored, bringing the max per-worker edge load
  below the threshold on a graph edge-range splitting alone cannot fix
  (split never changes the LOGICAL worker loads);
* crossness accounting is honest: the static cross-worker count from
  ``pair_counts`` equals the measured ``msgs_combined`` of a full
  first broadcast superstep with mirroring off.
"""
import numpy as np
import pytest

from repro.api import Engine, config_of
from repro.core import cost_model
from repro.core.exec import crossness_report
from repro.graph import generators as gen
from repro.graph import partitioner as pmod
from repro.graph.structs import (apply_delta, canonical_labels,
                                 fold_delta, partition)
from test_service import assert_same_partition, churn_delta


def _crossness_of(pg, weight=None):
    return cost_model.crossness(np.asarray(pg.pair_counts), weight)


# -- protocol / registry ---------------------------------------------------

def test_registry_covers_every_balance_mode():
    assert pmod.BALANCES == ("hash", "edges", "edges+refine", "split",
                             "vertex-cut")
    for balance in pmod.BALANCES:
        p = pmod.partitioner_for(balance, tau=8, seed=1,
                                 split_factor=1.3)
        assert isinstance(p, pmod.Partitioner)
        assert p.name == balance
    with pytest.raises(ValueError, match="unknown balance"):
        pmod.partitioner_for("metis")


def test_assign_seam_shapes_and_split_specs():
    g = gen.powerlaw(300, avg_deg=5, seed=1).symmetrized()
    M = 4
    for balance, kind in [("hash", "none"), ("edges", "none"),
                          ("edges+refine", "none"),
                          ("split", "edge_ranges"),
                          ("vertex-cut", "vertex_cut")]:
        perm, spec = pmod.partitioner_for(balance).assign(g, M)
        assert perm.shape == (g.n,) and perm.dtype == np.int64
        # block relabeling: every worker holds at most n_loc vertices
        n_loc = -(-g.n // M)
        assert np.bincount(perm // n_loc, minlength=M).max() <= n_loc
        assert len(np.unique(perm)) == g.n
        assert spec.kind == kind
        assert (spec.vc_thresh is not None) == (kind == "vertex_cut")


def test_partition_rejects_unknown_balance():
    g = gen.chain(16)
    with pytest.raises(ValueError):
        partition(g, 2, balance="nope")


# -- locality refinement ---------------------------------------------------

def test_refinement_descends_crossness_at_equal_balance():
    g = gen.powerlaw(600, avg_deg=6, seed=1, alpha=1.6).symmetrized()
    M = 8
    pg_e = partition(g, M, tau=None, layout="csr", balance="edges")
    pg_r = partition(g, M, tau=None, layout="csr",
                     balance="edges+refine")
    assert _crossness_of(pg_r) < _crossness_of(pg_e)
    le, lr = pg_e.edge_load(), pg_r.edge_load()
    assert lr.max() <= le.max()          # the refiner's load cap
    assert np.bincount(np.asarray(pg_r.perm) // pg_r.n_loc,
                       minlength=M).max() <= pg_r.n_loc


def test_refine_assignment_respects_caps_and_makes_swaps():
    # n divides M exactly: every slot is taken, so only SWAPS can move
    g = gen.powerlaw(640, avg_deg=6, seed=3, alpha=1.6).symmetrized()
    M, n_loc = 8, 80
    deg = np.bincount(g.src, minlength=g.n)
    cost = cost_model.vertex_cost(deg, M, None)
    assign = cost_model.greedy_assign(cost, M, n_loc)
    assert np.bincount(assign, minlength=M).min() == n_loc  # full
    W = cost_model.pair_weight(M)

    def J(owner):
        n_ids = M * g.n  # crossness from scratch over distinct pairs
        key = np.unique(owner[g.src].astype(np.int64) * g.n + g.dst)
        pc = np.zeros((M, M), np.int64)
        np.add.at(pc, (key // g.n, owner[key % g.n]), 1)
        return cost_model.crossness(pc, W)

    refined, moves = cost_model.refine_assignment(
        g.src, g.dst, assign, M, n_loc, cost, weight=W, rounds=3)
    assert moves > 0
    assert J(refined) < J(assign)
    counts = np.bincount(refined, minlength=M)
    assert counts.max() <= n_loc
    loads0 = np.zeros(M, np.int64)
    np.add.at(loads0, assign, cost)
    loads1 = np.zeros(M, np.int64)
    np.add.at(loads1, refined, cost)
    assert loads1.max() <= loads0.max()


def test_refinement_prices_cross_host_lanes_higher():
    W = cost_model.pair_weight(8, hosts=2, cross_host_weight=4.0)
    assert W[0, 0] == 0.0
    assert W[0, 1] == 1.0          # same host block
    assert W[0, 4] == 4.0          # across the host boundary
    g = gen.powerlaw(600, avg_deg=6, seed=2, alpha=1.6).symmetrized()
    pg_e = partition(g, 8, tau=None, layout="csr", balance="edges",
                     hosts=2)
    pg_r = partition(g, 8, tau=None, layout="csr",
                     balance="edges+refine", hosts=2)
    # refinement descends the HOST-weighted objective it was priced with
    assert _crossness_of(pg_r, W) < _crossness_of(pg_e, W)


# -- vertex-cut for mega-hubs ----------------------------------------------

def test_vertex_cut_tames_mega_hub_below_split_threshold():
    g = gen.star(401).symmetrized()   # hub degree 400
    M = 8
    vc_t = pmod.VertexCutPartitioner(split_factor=1.1).vc_thresh(g, M)
    assert np.bincount(g.src, minlength=g.n).max() > vc_t
    pg_e = partition(g, M, tau=None, layout="csr", balance="edges")
    pg_s = partition(g, M, tau=None, layout="csr", balance="split",
                     split_factor=1.1)
    pg_v = partition(g, M, tau=None, layout="csr", balance="vertex-cut",
                     split_factor=1.1)
    # a single vertex above the threshold: no vertex-disjoint assignment
    # (and no edge-range split — it never moves logical rows) can fix it
    assert pg_e.edge_load().max() > vc_t
    assert pg_s.edge_load().max() > vc_t
    # the cut spreads the hub's fan-out rows across hosting workers
    assert pg_v.edge_load().max() <= vc_t
    assert pg_v.tau == vc_t
    assert int((np.asarray(pg_v.mir_nworkers) > 0).sum()) >= 1
    # master/replica combine keeps the Theorem-1 lane bound
    assert np.asarray(pg_v.mir_nworkers).max() <= min(M, 400)
    # placement never changes semantics
    eng = Engine(config_of(pg_e))
    ref = canonical_labels(pg_e, eng.run("hashmin", pg_e).state)
    for pg in (pg_s, pg_v):
        got = canonical_labels(pg, Engine(config_of(pg)).run(
            "hashmin", pg).state)
        np.testing.assert_array_equal(got, ref)


def test_vertex_cut_threshold_composes_with_tau():
    g = gen.star(401).symmetrized()
    vc_t = pmod.VertexCutPartitioner(split_factor=1.1).vc_thresh(g, 8)
    # explicit tau below the cut threshold wins; above it the cut wins
    pg_lo = partition(g, 8, tau=5, layout="csr", balance="vertex-cut",
                      split_factor=1.1)
    assert pg_lo.tau == 5
    pg_hi = partition(g, 8, tau=10 * vc_t, layout="csr",
                      balance="vertex-cut", split_factor=1.1)
    assert pg_hi.tau == vc_t


def test_vertex_cut_fold_parity_under_pinned_perm():
    """``pg.tau`` embeds the vertex-cut fold, so the pinned-perm rebuild
    (and therefore ``fold_delta``) reproduces a cut partition exactly."""
    g = gen.star(401).symmetrized()
    pg = partition(g, 8, tau=None, layout="csr", balance="vertex-cut",
                   split_factor=1.1)
    delta = churn_delta(g, 0.04, 7)
    folded = fold_delta(pg, delta)
    fresh = partition(apply_delta(g, delta), 8, tau=pg.tau,
                      layout="csr", balance="vertex-cut",
                      split_factor=1.1, perm=pg.perm)
    assert_same_partition(folded, fresh)


# -- honest crossness accounting -------------------------------------------

def test_crossness_report_matches_measured_messages():
    """The static cross-worker count IS the combined-message count of a
    full broadcast superstep: superstep 0 of Hash-Min (every vertex
    active) with mirroring off must measure exactly it."""
    g = gen.powerlaw(400, avg_deg=6, seed=4, alpha=1.7).symmetrized()
    for balance in ("hash", "edges", "edges+refine"):
        pg = partition(g, 8, tau=None, layout="csr", balance=balance)
        rep = crossness_report(pg, 8)
        eng = Engine(config_of(pg, use_mirroring=False))
        res = eng.run("hashmin", pg, max_supersteps=1)
        assert rep["cross_worker"] == int(res.stats["msgs_combined"]), \
            balance
        assert rep["total"] == int(np.asarray(pg.pair_counts).sum())
        assert 0.0 <= rep["cross_device_frac"] \
            <= rep["cross_worker_frac"] <= 1.0


def test_crossness_report_levels_nest():
    g = gen.powerlaw(400, avg_deg=6, seed=5, alpha=1.7).symmetrized()
    pg = partition(g, 8, tau=None, layout="csr", balance="edges",
                   hosts=2)
    rep = crossness_report(pg, (2, 4))
    assert rep["cross_host"] <= rep["cross_device"] <= rep["cross_worker"]
    assert rep["H"] == 2 and rep["D"] == 8
    with pytest.raises(ValueError, match="divide"):
        crossness_report(pg, 3)

"""Vertex-id precision regression: ids above 2^24 are not representable in
float32, so any float round-trip in an id-carrying min-combine silently
merges distinct components (16_777_216.0 == float32(16_777_217)).  The
id-carrying algorithms must combine in the integer dtype end to end.

The graph is built so the *relabeled* id space (what the combiner actually
sees) contains the adjacent ids 2^24 and 2^24 + 1 in different components,
and the message path itself must transport an id > 2^24 exactly.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.plan import identity_of
from repro.graph.structs import Graph, partition

B24 = 16_777_216                      # 2^24: first float32-unrepresentable+1


def _label_of(pg, labels, new_id):
    """Component label of the vertex whose *relabeled* id is ``new_id``."""
    return int(np.asarray(labels).reshape(-1)[new_id])


@pytest.mark.slow  # 16.7M-vertex host arrays: nightly
def test_hashmin_distinguishes_ids_straddling_2_24():
    """Two components whose min ids are 2^24 and 2^24 + 1 must keep
    distinct labels, and the +1 label must survive being *sent* through
    the combine channel.  Fails on a float32 id path."""
    from repro.algorithms.hashmin import hashmin

    n = B24 + 4
    M = 2
    # partition() relabels by a seeded permutation; pick old ids that land
    # exactly on the new ids we need
    seed = 0
    perm = np.random.RandomState(seed).permutation(n)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    a = inv[B24]          # new id 2^24          (singleton component)
    b = inv[B24 + 1]      # new id 2^24 + 1      (component with d)
    d = inv[B24 + 3]      # new id 2^24 + 3      (receives b's id)

    src = np.array([b, d], np.int64)
    dst = np.array([d, b], np.int64)
    g = Graph(n, src, dst)
    pg = partition(g, M, tau=None, seed=seed, layout="csr")

    labels, stats, n_ss = hashmin(pg, use_mirroring=False, backend="dense")
    la = _label_of(pg, labels, B24)
    lb = _label_of(pg, labels, B24 + 1)
    ld = _label_of(pg, labels, B24 + 3)
    # exact component labels: the singleton keeps 2^24, the pair collapses
    # to 2^24 + 1 — under a float32 round-trip all three read 2^24
    assert la == B24
    assert lb == B24 + 1, f"id 2^24+1 collapsed to {lb} (float32 merge)"
    assert ld == B24 + 1, f"message path rounded 2^24+1 to {ld}"
    assert lb != la, "distinct components merged"


def test_identity_of_int_is_exact_sentinel():
    """The int min identity is iinfo.max (an exact int), not an inf cast."""
    ident = identity_of("min", jnp.int32)
    assert ident.dtype == jnp.int32
    assert int(ident) == np.iinfo(np.int32).max
    assert int(identity_of("max", jnp.int32)) == np.iinfo(np.int32).min


def test_min_combine_int_exact_small():
    """In-process miniature of the 2^24 scenario: the channel min-combine
    over int32 values preserves adjacent large ids exactly (pure channel
    check, no giant graph — runs in the fast suite)."""
    from repro.core.channels import push_combined

    M, n_loc = 2, 2
    # one source worker sends id 2^24+1 to vertex 0 (worker 0)
    targets = jnp.array([[0], [0]], jnp.int32)
    values = jnp.array([[B24 + 2], [B24 + 1]], jnp.int32)
    mask = jnp.array([[True], [True]])
    for backend in ("dense", "pallas"):
        inbox, stats = push_combined(targets, values, mask, "min",
                                     M, n_loc, backend=backend)
        assert inbox.dtype == jnp.int32
        assert int(inbox[0, 0]) == B24 + 1, backend
    # float32 provably cannot represent the winner — the old failure mode
    assert int(jnp.float32(B24 + 1)) == B24

"""Graph container / partition invariants across both edge layouts."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import generators as gen
from repro.graph.structs import partition


def _edge_key(g):
    return np.sort(g.src.astype(np.int64) * g.n + g.dst)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_symmetrized_idempotent(seed, weighted):
    g = gen.powerlaw(150, avg_deg=5, seed=seed % 97, weighted=weighted)
    s1 = g.symmetrized()
    s2 = s1.symmetrized()
    np.testing.assert_array_equal(_edge_key(s1), _edge_key(s2))
    if weighted:
        o1 = np.argsort(s1.src.astype(np.int64) * g.n + s1.dst)
        o2 = np.argsort(s2.src.astype(np.int64) * g.n + s2.dst)
        np.testing.assert_array_equal(s1.weight[o1], s2.weight[o2])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_symmetrized_weight_symmetric(seed):
    g = gen.powerlaw(150, avg_deg=5, seed=seed % 89,
                     weighted=True).symmetrized()
    w_of = {}
    for s, d, w in zip(g.src, g.dst, g.weight):
        w_of[(int(s), int(d))] = float(w)
    for (s, d), w in w_of.items():
        assert (d, s) in w_of, "missing reverse edge"
        assert w_of[(d, s)] == w, "asymmetric weight"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8]),
       st.sampled_from([None, 6, 16]))
def test_partition_conserves_edges_and_degrees(seed, M, tau):
    g = gen.powerlaw(200, avg_deg=6, seed=seed % 83,
                     weighted=True).symmetrized()
    for layout in ("padded", "csr"):
        pg = partition(g, M, tau=tau, seed=seed % 7, layout=layout)
        # every edge appears exactly once in the full adjacency,
        # and exactly once in the Ch_msg/mirror split
        n_all = int(np.asarray(pg.all_mask).sum())
        n_eg = int(np.asarray(pg.eg_mask).sum())
        n_mir = int(np.asarray(pg.mir_emask).sum())
        assert n_all == g.m, layout
        assert n_eg + n_mir == g.m, layout
        # degrees survive the relabeling
        deg = np.zeros(pg.n_pad, np.int64)
        deg[: g.n] = np.bincount(pg.perm[g.src], minlength=g.n)
        np.testing.assert_array_equal(np.asarray(pg.deg).reshape(-1), deg)
        assert int(np.asarray(pg.vmask).sum()) == g.n


def test_csr_equals_padded_rows_concatenated():
    """Same seed => same sort => csr flat arrays are exactly the padded
    rows with the padding removed (and local ids globalized)."""
    g = gen.powerlaw(250, avg_deg=6, seed=3, weighted=True).symmetrized()
    M = 4
    pp = partition(g, M, tau=8, seed=0, layout="padded")
    pc = partition(g, M, tau=8, seed=0, layout="csr")
    n_loc = pp.n_loc
    for kind in ("eg", "all"):
        mask = np.asarray(getattr(pp, f"{kind}_mask"))
        src_p = np.asarray(getattr(pp, f"{kind}_src"))
        row_w = np.broadcast_to(np.arange(M)[:, None], mask.shape)
        np.testing.assert_array_equal(
            (row_w * n_loc + src_p)[mask],
            np.asarray(getattr(pc, f"{kind}_src")))
        np.testing.assert_array_equal(
            np.asarray(getattr(pp, f"{kind}_dst"))[mask],
            np.asarray(getattr(pc, f"{kind}_dst")))
        np.testing.assert_array_equal(
            np.asarray(getattr(pp, f"{kind}_w"))[mask],
            np.asarray(getattr(pc, f"{kind}_w")))
        off = getattr(pc, f"{kind}_off")
        np.testing.assert_array_equal(np.diff(off), mask.sum(axis=1))
    # mirror edges: local dst on hosting worker w <-> global w*n_loc + dst
    mmask = np.asarray(pp.mir_emask)
    row_w = np.broadcast_to(np.arange(M)[:, None], mmask.shape)
    np.testing.assert_array_equal(np.asarray(pp.mir_esrc)[mmask],
                                  np.asarray(pc.mir_esrc))
    np.testing.assert_array_equal(
        (row_w * n_loc + np.asarray(pp.mir_edst))[mmask],
        np.asarray(pc.mir_edst))
    np.testing.assert_array_equal(np.diff(pc.mir_eoff), mmask.sum(axis=1))
    # per-worker slices really belong to that worker
    for w in range(M):
        sl = slice(int(pc.all_off[w]), int(pc.all_off[w + 1]))
        assert (np.asarray(pc.all_src[sl]) // n_loc == w).all()


def test_partition_rejects_unknown_layout():
    g = gen.chain(16)
    with pytest.raises(ValueError):
        partition(g, 2, layout="coo")


def test_pair_counts_bound_routed_traffic():
    """pair_counts[s, d] is exactly the number of distinct (source worker,
    destination vertex) pairs of the full adjacency — the static cap the
    routed sharded exchange sizes its all_to_all lanes from."""
    g = gen.powerlaw(240, avg_deg=6, seed=4, weighted=True).symmetrized()
    for M in (4, 8):
        pg = partition(g, M, tau=10, seed=1, layout="csr")
        pc = pg.pair_counts
        assert pc.shape == (M, M) and (pc >= 0).all()
        src = np.asarray(pg.all_src)
        dst = np.asarray(pg.all_dst)
        pairs = set(zip((src // pg.n_loc).tolist(), dst.tolist()))
        ref = np.zeros((M, M), np.int64)
        for sw, d in pairs:
            ref[sw, d // pg.n_loc] += 1
        np.testing.assert_array_equal(pc, ref)
        # total distinct pairs can never exceed the edge count
        assert pc.sum() == len(pairs) <= g.m


def test_affinity_groups_recover_planted_host_blocks():
    """Host-topology-aware placement: ``affinity_groups`` must put
    heavy-communicating worker pairs in one host block.  A planted
    two-community affinity matrix (heavy within the communities,
    noise elsewhere) is recovered exactly."""
    from repro.core import cost_model

    rng = np.random.RandomState(0)
    M, H = 8, 2
    groups = [(0, 3, 5, 6), (1, 2, 4, 7)]
    aff = rng.randint(0, 3, (M, M)).astype(np.int64)
    for grp in groups:
        for i in grp:
            for j in grp:
                if i != j:
                    aff[i, j] += 100
    aff = aff + aff.T
    np.fill_diagonal(aff, 0)
    order = cost_model.affinity_groups(aff, H)
    blocks = {frozenset(order[:4].tolist()), frozenset(order[4:].tolist())}
    assert blocks == {frozenset(g) for g in groups}


def test_partition_hosts_is_placement_only_and_never_worse():
    """``partition(hosts=H)`` relabels workers only: the vertex->worker
    *content* is a permutation of the host-oblivious partition (same
    sorted per-worker loads, same edges), and the intra-host share of
    the worker-pair traffic matrix is >= the oblivious contiguous
    grouping's (affinity_groups falls back to identity, so host-aware
    placement can never lose in its own proxy)."""
    from repro.core import cost_model

    g = gen.powerlaw(300, avg_deg=6, seed=3, weighted=True).symmetrized()
    M, H = 8, 2
    T = M // H

    def intra(pc):
        aff = cost_model.worker_affinity(pc)
        return sum(aff[h * T:(h + 1) * T, h * T:(h + 1) * T].sum()
                   for h in range(H))

    for balance in ("hash", "edges"):
        base = partition(g, M, tau=10, seed=1, layout="csr",
                         balance=balance)
        host = partition(g, M, tau=10, seed=1, layout="csr",
                         balance=balance, hosts=H)
        assert base.hosts is None and host.hosts == H
        # placement only: same multiset of per-worker edge loads, every
        # edge conserved
        assert sorted(base.edge_load().tolist()) == \
            sorted(host.edge_load().tolist())
        assert np.asarray(host.all_src).shape == \
            np.asarray(base.all_src).shape
        assert len(set(host.perm.tolist())) == g.n
        assert 0 <= host.perm.min() and host.perm.max() < M * host.n_loc
        # host-aware grouping never scores below the oblivious order
        assert intra(host.pair_counts) >= intra(base.pair_counts)

    with pytest.raises(ValueError):
        partition(g, M, hosts=3)

"""The persistent graph service + the streaming delta fold.

* ``fold_delta(pg, delta)`` must equal a full re-``partition()`` of the
  mutated edge list with the SAME relabeling — exact array equality
  (csr folds incrementally; padded/split rebuild under the pinned perm),
  with ``pair_counts`` allowed to stay a monotone upper bound.
* Queries can never straddle a mutation epoch: everything served by one
  pump() reads exactly one snapshot.
* After warmup, admission and folds never re-trace (the frozen
  ShardProfile contract).
* Batched SSSP / PPR / ego answers match independent oracles.
"""
import numpy as np
import pytest

from conftest import sweep, union_find_cc
from repro.api import Engine, EngineConfig, config_of
from repro.core.service import GraphClient, GraphService, Query
from repro.graph import generators as gen
from repro.graph.structs import (EdgeDelta, apply_delta, fold_delta,
                                 partition)

ARRAY_FIELDS = (
    "perm", "deg", "vmask", "eg_src", "eg_dst", "eg_mask", "eg_w",
    "all_src", "all_dst", "all_mask", "all_w", "eg_off", "all_off",
    "mir_ids", "mir_slot_of", "mir_nworkers",
    "mir_esrc", "mir_edst", "mir_emask", "mir_ew", "mir_eoff")


def churn_delta(g, frac, seed, symmetric=True):
    """Remove ``frac`` of the (undirected) edges, add as many random
    ones — both directions, like the service's streamed mutations."""
    rng = np.random.RandomState(seed)
    lo = np.minimum(g.src, g.dst)
    hi = np.maximum(g.src, g.dst)
    key = np.unique(lo.astype(np.int64) * g.n + hi)
    k = max(int(len(key) * frac), 1)
    ridx = rng.choice(len(key), size=k, replace=False)
    a_s = rng.randint(0, g.n, size=k)
    a_d = rng.randint(0, g.n, size=k)
    keep = a_s != a_d
    a_w = (rng.rand(int(keep.sum())).astype(np.float32) + 0.01
           if g.weight is not None else None)
    d = EdgeDelta(add_src=a_s[keep], add_dst=a_d[keep], add_w=a_w,
                  rem_src=key[ridx] // g.n, rem_dst=key[ridx] % g.n)
    return d.symmetrized() if symmetric else d


def assert_same_partition(pa, pb):
    for f in ARRAY_FIELDS:
        a, b = getattr(pa, f), getattr(pb, f)
        if a is None or b is None:
            assert a is None and b is None, f
            continue
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"field {f!r} diverged from the fresh partition"
    assert (pa.M, pa.n_loc, pa.tau, pa.layout, pa.balance) == \
           (pb.M, pb.n_loc, pb.tau, pb.layout, pb.balance)


@pytest.mark.parametrize("layout,balance", [
    ("csr", "hash"), ("csr", "edges"), ("csr", "edges+refine"),
    ("csr", "split"), ("csr", "vertex-cut"), ("padded", "hash")])
def test_fold_equals_full_repartition(layout, balance):
    for seed in range(sweep(6)):
        g = gen.powerlaw(300, avg_deg=5, seed=seed,
                         weighted=True).symmetrized()
        pg = partition(g, 8, tau=8, seed=seed, layout=layout,
                       balance=balance, split_factor=1.1)
        delta = churn_delta(g, 0.05, seed + 100)
        folded = fold_delta(pg, delta)
        g2 = apply_delta(g, delta)
        fresh = partition(g2, 8, tau=8, layout=layout, balance=balance,
                          split_factor=1.1, perm=pg.perm)
        assert_same_partition(folded, fresh)
        # pair_counts only ever over-counts (mirror caps stay safe)
        if folded.pair_counts is not None:
            assert np.all(np.asarray(folded.pair_counts)
                          >= np.asarray(fresh.pair_counts))
        # and the folded graph computes the same components
        eng = Engine(config_of(pg))
        la = eng.run("hashmin", folded).state
        lb = eng.run("hashmin", fresh).state
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_fold_no_mirror_fast_path():
    """tau=None (sentinel, no mirroring — the service default) takes the
    channel-aliasing fast path; still bitwise equal to a fresh run."""
    for seed in range(sweep(4)):
        g = gen.powerlaw(280, avg_deg=5, seed=seed,
                         weighted=True).symmetrized()
        pg = partition(g, 8, layout="csr", balance="edges")
        delta = churn_delta(g, 0.05, seed + 50)
        folded = fold_delta(pg, delta)
        fresh = partition(apply_delta(g, delta), 8, tau=pg.tau,
                          layout="csr", balance="edges", perm=pg.perm)
        assert_same_partition(folded, fresh)
        # the alias is real: Ch_msg shares the full-adjacency buffers
        assert folded.eg_src is folded.all_src


def test_fold_add_only_and_remove_only():
    g = gen.powerlaw(240, avg_deg=4, seed=2, weighted=True).symmetrized()
    pg = partition(g, 4, tau=6, seed=0, layout="csr", balance="edges")
    rng = np.random.RandomState(0)
    adds = EdgeDelta(add_src=rng.randint(0, g.n, 40),
                     add_dst=rng.randint(1, g.n, 40),
                     add_w=rng.rand(40).astype(np.float32)).symmetrized()
    rems = churn_delta(g, 0.03, 5)
    rems = EdgeDelta(rem_src=rems.rem_src, rem_dst=rems.rem_dst)
    for d in (adds, rems):
        folded = fold_delta(pg, d)
        fresh = partition(apply_delta(g, d), 4, tau=6, layout="csr",
                          balance="edges", perm=pg.perm)
        assert_same_partition(folded, fresh)


def _ppr_oracle(g, src, alpha, iters):
    deg = np.bincount(g.src, minlength=g.n)
    pr = np.zeros(g.n)
    pr[src] = 1.0
    restart = pr.copy()
    for _ in range(iters):
        contrib = np.where(deg > 0, pr / np.maximum(deg, 1), 0.0)
        inbox = np.zeros(g.n)
        np.add.at(inbox, g.dst, contrib[g.src])
        pr = alpha * restart + (1 - alpha) * inbox
    return pr


@pytest.fixture(scope="module")
def service():
    g = gen.powerlaw(300, avg_deg=5, seed=3, weighted=True).symmetrized()
    svc = GraphService(g, M=4,
                       config=EngineConfig(layout="csr", balance="edges",
                                           devices=1),
                       buckets=(2, 4), ppr_iters=8, max_supersteps=64,
                       profile_slack=2.0)
    svc.warmup()
    return svc


def test_batched_queries_match_oracles(service):
    svc = service
    client = GraphClient(svc)
    res = client.request([Query("sssp", 0), Query("sssp", 11),
                          Query("ppr", 7), Query("ego", 5)])
    eng = Engine(config_of(svc.pg, devices=None))
    for r in res[:2]:
        ref = eng.run("sssp", svc.pg,
                      source=int(svc.pg.perm[r.query.source]))
        want = np.asarray(ref.state).reshape(-1)[svc.pg.perm]
        assert np.allclose(r.value, want, equal_nan=True)
    g = svc.snapshot_graph()
    want = _ppr_oracle(g, 7, svc.ppr_alpha, svc.ppr_iters)
    assert np.allclose(res[2].value, want, atol=1e-5)
    roots = union_find_cc(g.n, g.src, g.dst)
    sizes = np.bincount(roots, minlength=g.n)
    assert res[3].value == (int(roots[5]), int(sizes[roots[5]]))


def test_result_cache_and_coalescing(service):
    svc = service
    client = GraphClient(svc)
    a = client.sssp(21)
    assert not a.cached
    b = client.sssp(21)
    assert b.cached and np.array_equal(a.value, b.value)
    # duplicates inside one batch coalesce to one lane
    res = client.request([Query("ppr", 33), Query("ppr", 33)])
    assert svc.last_pump["lanes_ppr"] == 1
    assert np.array_equal(res[0].value, res[1].value)


def test_epoch_barrier_no_snapshot_mix(service):
    svc = service
    g0 = svc.snapshot_graph()
    e0 = svc.epoch
    t_pre = svc.submit([Query("sssp", 17)])
    svc.pump()
    pre = svc.take_result(t_pre[0])
    assert pre.epoch == e0

    delta = churn_delta(g0, 0.05, 42)
    svc.mutate(delta)
    # queued both before and after another mutate: ONE pump serves them
    # all AFTER every pending fold — never a mix
    t_a = svc.submit([Query("sssp", 17)])
    svc.mutate(churn_delta(g0, 0.02, 43))
    t_b = svc.submit([Query("ppr", 9), Query("ego", 17)])
    svc.pump()
    ra = svc.take_result(t_a[0])
    rb = [svc.take_result(t) for t in t_b]
    assert ra.epoch == svc.epoch and all(r.epoch == svc.epoch for r in rb)
    assert svc.epoch == e0 + 1  # both folds collapsed into one barrier

    # pre-fold answer was computed on the OLD snapshot, post-fold on the
    # NEW one — each matches its own oracle exactly
    eng = Engine(config_of(svc.pg, devices=None))
    pg_old = partition(g0, 4, tau=svc.pg.tau, layout="csr",
                       balance="edges")
    want_old = np.asarray(
        eng.run("sssp", pg_old,
                source=int(pg_old.perm[17])).state).reshape(-1)[pg_old.perm]
    assert np.allclose(pre.value, want_old, equal_nan=True)
    want_new = np.asarray(
        eng.run("sssp", svc.pg,
                source=int(svc.pg.perm[17])).state
    ).reshape(-1)[svc.pg.perm]
    assert np.allclose(ra.value, want_new, equal_nan=True)
    want_ppr = _ppr_oracle(svc.snapshot_graph(), 9, svc.ppr_alpha,
                           svc.ppr_iters)
    assert np.allclose(rb[0].value, want_ppr, atol=1e-5)


def test_no_retrace_across_batches_and_folds(service):
    svc = service
    client = GraphClient(svc)
    traces = svc.traces
    client.request([Query("sssp", 40), Query("ppr", 41),
                    Query("ego", 42)])
    svc.mutate(churn_delta(svc.snapshot_graph(), 0.03, 77))
    client.request([Query("sssp", 43), Query("ppr", 44),
                    Query("ego", 45)])
    assert svc.traces == traces, "resident executors re-traced"


def _make_service(**kw):
    g = gen.powerlaw(300, avg_deg=5, seed=3, weighted=True).symmetrized()
    svc = GraphService(g, M=4,
                       config=EngineConfig(layout="csr", balance="edges",
                                           devices=1),
                       buckets=(2,), ppr_iters=6, max_supersteps=64,
                       profile_slack=2.0, **kw)
    svc.warmup()
    return svc


def test_elastic_repartition_no_retrace_and_parity():
    """Telemetry-driven elastic repartition: pump() fires it from the
    measured per-worker message load, the resident executors never
    re-trace across it, and post-repartition answers equal a
    fresh-partition Engine run."""
    svc = _make_service(rebalance_threshold=1.0)
    client = GraphClient(svc)
    client.request([Query("sssp", 0), Query("ppr", 7)])
    # a power-law load is never perfectly flat: max/mean > 1.0 fires
    assert svc.repartitions >= 1
    traces = svc.traces
    reps = svc.repartitions
    svc.mutate(churn_delta(svc.snapshot_graph(), 0.05, 21))
    res = client.request([Query("sssp", 12), Query("ppr", 29),
                          Query("ego", 4)])
    assert svc.repartitions > reps
    assert svc.traces == traces, \
        "elastic repartition must reshard, never re-trace"
    # answers on the repartitioned residency == fresh-partition run
    eng = Engine(config_of(svc.pg, devices=None))
    want = np.asarray(
        eng.run("sssp", svc.pg,
                source=int(svc.pg.perm[12])).state).reshape(-1)[svc.pg.perm]
    assert np.allclose(res[0].value, want, equal_nan=True)
    want_ppr = _ppr_oracle(svc.snapshot_graph(), 29, svc.ppr_alpha,
                           svc.ppr_iters)
    assert np.allclose(res[1].value, want_ppr, atol=1e-5)


def test_rebalance_threshold_gates_the_trigger():
    svc = _make_service(rebalance_threshold=1e9)
    client = GraphClient(svc)
    client.request([Query("sssp", 0), Query("ppr", 7)])
    assert svc.repartitions == 0          # never drifts THAT far
    assert svc.last_batch["stats"]["per_worker_total"].size == svc.M
    svc.repartition()                     # manual trigger still works
    assert svc.repartitions == 1


def test_repartition_retightens_pair_counts():
    """Satellite-6 property: folds only ever GROW the monotone
    ``pair_counts`` caps (removals leave stale pairs behind);
    ``repartition()`` shrinks them back to fresh-partition values."""
    svc = _make_service()
    g0 = svc.snapshot_graph()
    svc.mutate(churn_delta(g0, 0.08, 11))
    svc.pump()
    fresh = svc.engine.partition(svc.g, svc.M, tau=svc.tau,
                                 seed=svc.seed)
    folded_pc = np.asarray(svc.pg.pair_counts)
    fresh_pc = np.asarray(fresh.pair_counts)
    assert np.all(folded_pc >= fresh_pc)
    assert np.any(folded_pc > fresh_pc), \
        "churn with removals should leave stale caps behind"
    svc.repartition()
    assert np.array_equal(np.asarray(svc.pg.pair_counts), fresh_pc)
    assert_same_partition(svc.pg, fresh)


def test_profile_overflow_rewarns_and_stays_correct():
    g = gen.powerlaw(200, avg_deg=4, seed=5, weighted=True).symmetrized()
    svc = GraphService(g, M=4,
                       config=EngineConfig(layout="csr", balance="edges",
                                           devices=1),
                       buckets=(2,), ppr_iters=6, max_supersteps=64,
                       profile_slack=1.01)
    svc.warmup()
    client = GraphClient(svc)
    rng = np.random.RandomState(9)
    k = g.m  # double the edge count: guaranteed to blow the envelope
    a_s = rng.randint(0, g.n, size=k)
    a_d = rng.randint(1, g.n, size=k)
    keep = a_s != a_d
    svc.mutate(EdgeDelta(
        add_src=a_s[keep], add_dst=a_d[keep],
        add_w=rng.rand(int(keep.sum())).astype(np.float32) + 0.01
    ).symmetrized())
    r = client.sssp(3)
    assert r.epoch == 1
    eng = Engine(config_of(svc.pg, devices=None))
    want = np.asarray(
        eng.run("sssp", svc.pg,
                source=int(svc.pg.perm[3])).state).reshape(-1)[svc.pg.perm]
    assert np.allclose(r.value, want, equal_nan=True)


def test_service_rejects_unsupported_configs():
    g = gen.chain(16)
    with pytest.raises(ValueError):
        GraphService(g, M=4, config=EngineConfig(layout="padded",
                                                 devices=1))
    with pytest.raises(ValueError):
        GraphService(g, M=4, config=EngineConfig(layout="csr",
                                                 backend="pallas",
                                                 devices=1))
    svc = GraphService(g, M=4, config=EngineConfig(layout="csr",
                                                   devices=1))
    with pytest.raises(ValueError):
        svc.submit([Query("nope", 0)])
    with pytest.raises(ValueError):
        svc.submit([Query("sssp", 99)])

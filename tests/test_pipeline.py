"""Double-buffered superstep pipeline: chunk-boundary edge cases and
exactness.

In-process tests run the one-visible-device configuration (devices=1 —
the conftest invariant); the routed-exchange round machinery, the chunk
tables, and the bsp stats fold are all exercised there because the round
loop and double buffer are independent of D.  Multi-device pipelined
parity (devices {2, 8}, all six algorithms, split balance) is pinned by
the shard_check tier1/full suites driven from test_conformance.py.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core import plan as planlib
from repro.core.plan import identity_of, scatter_op
from repro.graph import generators as gen
from repro.graph.structs import partition


def _pg(n=180, M=8, tau=8, layout="csr"):
    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    return partition(g, M, tau=tau, seed=0, layout=layout)


# ---------------------------------------------------------------------------
# routed scatter-combine: chunk-size edge cases, bitwise vs the dense ref
# ---------------------------------------------------------------------------

def _routed_scatter(pg, targets, values, valid, op, cap, pipeline):
    def mk(g):
        if hasattr(g, "axis"):          # the device-local sharded body
            def fn(t, v, ok):
                buf = exec_mod._routed_scatter_combine(g, t, v, ok, op,
                                                       cap=cap)
                return buf.reshape(g.m_loc, g.n_loc), {}
        else:                           # dense reference (shape tracing)
            def fn(t, v, ok):
                ident = identity_of(op, v.dtype)
                buf = jnp.full((g.n_pad,), ident, v.dtype)
                buf = scatter_op(op, buf, jnp.where(ok, t, 0),
                                 jnp.where(ok, v, ident))
                return buf.reshape(g.M, g.n_loc), {}
        return fn

    out, _ = exec_mod.apply_sharded(pg, mk, (targets, values, valid),
                                    devices=1, pipeline=pipeline)
    return np.asarray(out)


@pytest.mark.parametrize("op,dtype", [("min", np.int32), ("sum", np.int32)])
@pytest.mark.parametrize("cap", [1, 8, 1024])
def test_routed_scatter_pipeline_bitwise(op, dtype, cap):
    """cap=1: one lane per round (maximum rounds through the double
    buffer).  cap=8 with a hot destination: overflow adds rounds
    mid-pipeline.  cap=1024 >= L: a single round — the pipeline
    degenerates to prologue + epilogue.  All must be bitwise equal to
    the unpipelined path and to the dense scatter reference."""
    pg = _pg()
    rng = np.random.RandomState(7)
    L = 100
    targets = rng.randint(0, pg.n_pad, L).astype(np.int32)
    targets[::5] = 3          # hot destination: overflows small caps
    values = rng.randint(-50, 50, L).astype(dtype)
    valid = jnp.asarray(rng.rand(L) > 0.2)
    t, v = jnp.asarray(targets), jnp.asarray(values)

    seq = _routed_scatter(pg, t, v, valid, op, cap, pipeline=False)
    pipe = _routed_scatter(pg, t, v, valid, op, cap, pipeline=True)

    ident = np.asarray(identity_of(op, values.dtype))
    ref = np.full(pg.n_pad, ident, dtype)
    for i in range(L):
        if bool(np.asarray(valid)[i]):
            if op == "min":
                ref[targets[i]] = min(ref[targets[i]], values[i])
            else:
                ref[targets[i]] += values[i]
    ref = ref.reshape(pg.M, pg.n_loc)

    assert np.array_equal(seq, ref)
    assert np.array_equal(pipe, ref)


# ---------------------------------------------------------------------------
# routed fetch: the request-respond rounds under the double buffer
# ---------------------------------------------------------------------------

def _routed_fetch(pg, vals, targets, valid, cap, pipeline):
    def mk(g):
        if hasattr(g, "axis"):
            def fn(v, t, ok):
                return exec_mod._routed_fetch(g, v, t, ok, cap=cap), {}
        else:
            def fn(v, t, ok):
                flat = v.reshape(-1)
                ok_t = ok & (t >= 0) & (t < g.n_pad)
                got = flat[jnp.clip(t, 0, g.n_pad - 1)]
                return jnp.where(ok_t, got, jnp.zeros((), v.dtype)), {}
        return fn

    out, _ = exec_mod.apply_sharded(pg, mk, (vals, targets, valid),
                                    devices=1, pipeline=pipeline)
    return np.asarray(out)


@pytest.mark.parametrize("cap", [1, 8, 1024])
def test_routed_fetch_pipeline_bitwise(cap):
    pg = _pg()
    rng = np.random.RandomState(11)
    L = 96
    vals = jnp.asarray(rng.randn(pg.M, pg.n_loc).astype(np.float32) + 2.0)
    targets = rng.randint(0, pg.n_pad, L).astype(np.int32)
    targets[::4] = 5          # hot owner slot
    valid = jnp.asarray(rng.rand(L) > 0.3)
    t = jnp.asarray(targets)

    seq = _routed_fetch(pg, vals, t, valid, cap, pipeline=False)
    pipe = _routed_fetch(pg, vals, t, valid, cap, pipeline=True)

    flat = np.asarray(vals).reshape(-1)
    ref = np.where(np.asarray(valid), flat[targets], 0.0).astype(np.float32)
    assert np.array_equal(seq, ref)
    assert np.array_equal(pipe, ref)


# ---------------------------------------------------------------------------
# plan chunk tables: the static partition the pipelined exchange walks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunks", [1, 3, 64])
def test_stack_plans_chunk_tables_partition(chunks):
    """Every real exchange slot and every real plan row must land in
    exactly ONE chunk (chunks partition the combine work), and the
    chunk-local receive blocks must agree with the unchunked tables.
    chunks=64 >> xcap degenerates to one slot per chunk."""
    pg = _pg()
    D = 4
    m = pg.M // D
    plans = exec_mod._device_plans(pg, D, "eg", planlib.default_nb())
    meta_s, a_s = exec_mod._stack_plans(plans, m)
    meta_c, a_c = exec_mod._stack_plans(plans, m, chunks=chunks)
    C, ccap = meta_c["n_chunks"], meta_c["ccap"]
    assert C == -(-meta_s["xcap"] // ccap)

    for d in range(D):
        # exchange slots: same multiset of (dest device, local block) pairs
        assert a_c["cxval"][d].sum() == a_s["xval"][d].sum()
        assert a_c["crval"][d].sum() == a_s["rval"][d].sum()
        rb_s = sorted(a_s["rblk"][d][a_s["rval"][d]].tolist())
        rb_c = sorted(a_c["crblk"][d][a_c["crval"][d]].tolist())
        assert rb_c == rb_s
        # rows: each real row appears in exactly one chunk
        rows = a_c["crow"][d][a_c["crow_ok"][d]]
        assert sorted(rows.tolist()) == list(range(plans[d].n_rows))
        # chunk-local segment ids stay inside the chunk's segment count
        assert (a_c["crow_seg"][d][a_c["crow_ok"][d]] < meta_c["cs"]).all()


# ---------------------------------------------------------------------------
# end-to-end on one device: plan-path chunking + deferred stats fold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "dense"])
def test_hashmin_pipeline_bitwise_one_device(backend):
    from repro.algorithms.hashmin import hashmin
    pg = _pg()
    ref = hashmin(pg, backend=backend, devices=1)
    pipe = hashmin(pg, backend=backend, devices=1, pipeline=True)
    assert np.array_equal(np.asarray(ref[0]), np.asarray(pipe[0]))
    assert int(ref[2]) == int(pipe[2])
    for k in ref[1]:
        assert np.array_equal(np.asarray(ref[1][k]),
                              np.asarray(pipe[1][k])), k


def test_pagerank_pipeline_tolerance_one_device():
    from repro.algorithms.pagerank import pagerank
    pg = _pg()
    ref = pagerank(pg, n_iters=8, tol=0.0, backend="pallas", devices=1)
    pipe = pagerank(pg, n_iters=8, tol=0.0, backend="pallas", devices=1,
                    pipeline=True)
    assert np.allclose(np.asarray(ref[0]), np.asarray(pipe[0]),
                       rtol=1e-5, atol=1e-7)
    for k in ref[1]:    # stats stay integer-exact under the pipeline
        assert np.array_equal(np.asarray(ref[1][k]),
                              np.asarray(pipe[1][k])), k


def test_bsp_pipeline_fold_exact():
    """The deferred (hi, lo) limb fold must produce bit-identical totals:
    limb addition is associative and the initial pending slot all-zero,
    so shifting every superstep's add by one iteration changes nothing —
    including across the int32 lo-limb wrap."""
    def step(state, i):
        stats = {"big": jnp.int32(2 ** 30 + 12345),      # wraps lo fast
                 "per_w": jnp.full((4,), i + 1, jnp.int32),
                 "f": jnp.float32(0.25)}
        return state + 1, state + 1 >= jnp.int32(9), stats

    st0 = jnp.int32(0)
    st_s, tot_s, n_s, _ = bsp.run(step, st0, 50)
    st_p, tot_p, n_p, _ = bsp.run(step, st0, 50, pipeline=True)
    assert int(n_s) == int(n_p) == 9
    assert int(st_s) == int(st_p)
    for k in tot_s:
        assert np.array_equal(np.asarray(tot_s[k]), np.asarray(tot_p[k])), k

"""Honest message accounting + overflow-safe totals.

Two regression families:

* Mask-driven crossness: a genuine message whose payload equals the
  combine identity (a PageRank contribution of exactly 0.0 under sum, an
  id equal to iinfo.max under min) must still be counted — every combine
  path (dense scatter, plan/kernel, sorted segmented) counts distinct
  (source worker, destination) pairs by the SEND mask, never by comparing
  the combined value against the identity.

* int64 totals: per-superstep counts are int32, but ``bsp.run`` carries
  totals as (hi, lo) limb pairs and folds them into exact Python ints /
  numpy int64 on the host — multi-superstep totals past 2^31 must be
  exact, not wrapped.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsp
from repro.core.channels import (broadcast, push_combined,
                                 push_combined_flat, scatter_combine)
from repro.graph import generators as gen
from repro.graph.structs import partition


def _expected_pairs(targets, mask, M, n_loc):
    """Distinct (source worker, destination) pairs with >= 1 real message,
    destination owned by another worker — the honest combined count."""
    pairs = set()
    for w in range(targets.shape[0]):
        for k in range(targets.shape[1]):
            if mask[w, k]:
                pairs.add((w, int(targets[w, k])))
    return sum(1 for w, t in pairs if t // n_loc != w)


@pytest.mark.parametrize("op,ident_val", [
    ("sum", 0.0),                 # a 0.0 contribution IS a message
    ("min", np.float32(np.inf)),  # +inf payload under min
])
def test_identity_valued_messages_counted(op, ident_val):
    M, n_loc, K = 3, 8, 6
    rng = np.random.RandomState(0)
    targets = rng.randint(0, M * n_loc, (M, K)).astype(np.int32)
    mask = np.ones((M, K), bool)
    mask[1, 2] = False
    # EVERY payload equals the combine identity: value-driven accounting
    # would report zero combined messages
    values = np.full((M, K), ident_val, np.float32)
    want = _expected_pairs(targets, mask, M, n_loc)
    assert want > 0

    for backend in ("dense", "pallas"):
        _, stats = push_combined(jnp.asarray(targets), jnp.asarray(values),
                                 jnp.asarray(mask), op, M, n_loc,
                                 backend=backend)
        assert int(stats["msgs_combined"]) == want, backend
        assert int(np.asarray(stats["per_worker_combined"]).sum()) == want

    # flat (csr) twin, dense + sorted paths
    worker = np.repeat(np.arange(M), K).astype(np.int32)
    for backend in ("dense", "pallas"):
        _, stats = push_combined_flat(
            jnp.asarray(targets.reshape(-1)), jnp.asarray(values.reshape(-1)),
            jnp.asarray(mask.reshape(-1)), jnp.asarray(worker), op, M, n_loc,
            backend=backend)
        assert int(stats["msgs_combined"]) == want, f"flat/{backend}"

    # runtime-target scatter (sorted segmented combine)
    base = jnp.zeros((M, n_loc), jnp.float32)
    for backend in ("dense", "pallas"):
        _, stats = scatter_combine(base, jnp.asarray(targets),
                                   jnp.asarray(values), jnp.asarray(mask),
                                   op, M, n_loc, backend=backend)
        assert int(stats["msgs_combined"]) == want, f"scatter/{backend}"


def test_identity_payload_invariant_broadcast():
    """Channel-level: broadcasting all-zero values under sum must report
    exactly the same message statistics as broadcasting nonzero values
    with the same activity mask (plan/kernel path included)."""
    g = gen.powerlaw(150, avg_deg=5, seed=2, weighted=True).symmetrized()
    for layout in ("csr",):     # the padded twins share the counting code
        pg = partition(g, 4, tau=8, seed=0, layout=layout)
        active = pg.vmask
        ones = jnp.ones((pg.M, pg.n_loc), jnp.float32)
        zeros = jnp.zeros((pg.M, pg.n_loc), jnp.float32)
        for backend in ("dense", "pallas"):
            _, s1 = broadcast(pg, ones, active, op="sum", backend=backend)
            _, s0 = broadcast(pg, zeros, active, op="sum", backend=backend)
            for k in s1:
                np.testing.assert_array_equal(
                    np.asarray(s0[k]), np.asarray(s1[k]),
                    err_msg=f"{layout}/{backend}/{k}")


BIG = 2 ** 31 - 5


def test_totals_exceed_int32_exactly():
    """8 supersteps of a count just under 2^31 must total exactly
    8 * (2^31 - 5) — far past int32 — for scalars and (M,) arrays."""
    def step(state, i):
        stats = {"msgs_x": jnp.full((), BIG, jnp.int32),
                 "per_worker_x": jnp.full((3,), BIG, jnp.int32),
                 "float_x": jnp.ones((), jnp.float32)}
        return state + 1.0, state >= 7.0, stats

    final, stats, n, hist = bsp.run(step, jnp.zeros(()), 100)
    assert int(n) == 8
    assert isinstance(stats["msgs_x"], int)
    assert stats["msgs_x"] == 8 * BIG
    assert stats["msgs_x"] > 2 ** 31          # really crossed the boundary
    pw = np.asarray(stats["per_worker_x"])
    assert pw.dtype == np.int64
    np.testing.assert_array_equal(pw, np.full(3, 8 * BIG, np.int64))
    assert float(stats["float_x"]) == 8.0


def test_totals_small_counts_unchanged():
    """The limb accumulator is invisible for ordinary counts."""
    def step(state, i):
        return state + 1.0, state >= 2.0, {"m": jnp.full((), 7, jnp.int32)}

    _, stats, n, _ = bsp.run(step, jnp.zeros(()), 10)
    assert int(n) == 3 and stats["m"] == 21


def test_limb_carry_boundary():
    """Accumulation that repeatedly wraps the 32-bit boundary stays exact
    (the unsigned-compare carry)."""
    def step(state, i):
        return state + 1.0, state >= 99.0, {"m": jnp.full((), BIG, jnp.int32)}

    _, stats, n, _ = bsp.run(step, jnp.zeros(()), 1000)
    assert int(n) == 100
    assert stats["m"] == 100 * BIG

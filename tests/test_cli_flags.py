"""Argparse round-trips for the serve/train CLIs.

Pins the ``--reduced`` fix: the old ``action="store_true"`` with
``default=True`` parsed ``--reduced`` and *no flag at all* to the same
value and offered no way to turn it off — ``BooleanOptionalAction`` adds
``--no-reduced`` (train keeps ``--full`` as a back-compat alias).
"""
import pytest

from repro.launch import serve, train


@pytest.mark.parametrize("build", [serve.build_parser, train.build_parser],
                         ids=["serve", "train"])
def test_reduced_round_trip(build):
    ap = build()
    assert ap.parse_args([]).reduced is True
    assert ap.parse_args(["--reduced"]).reduced is True
    assert ap.parse_args(["--no-reduced"]).reduced is False


def test_train_full_alias_still_disables():
    ap = train.build_parser()
    assert ap.parse_args(["--full"]).reduced is False
    # later flag wins, both orders parse
    assert ap.parse_args(["--full", "--reduced"]).reduced is True


@pytest.mark.parametrize("build", [serve.build_parser, train.build_parser],
                         ids=["serve", "train"])
def test_other_flags_survive_the_switch(build):
    ap = build()
    args = ap.parse_args(["--no-reduced", "--batch", "3"])
    assert args.reduced is False and args.batch == 3

"""Argparse round-trips for the serve/train CLIs.

Pins the ``--reduced`` fix: the old ``action="store_true"`` with
``default=True`` parsed ``--reduced`` and *no flag at all* to the same
value and offered no way to turn it off — ``BooleanOptionalAction`` adds
``--no-reduced`` (train keeps ``--full`` as a back-compat alias).
"""
import pytest

from repro.launch import serve, serve_model, train


def test_serve_alias_reexports_serve_model():
    # launch/serve.py is a deprecated alias for the renamed model-serving
    # driver; both module paths must expose the same callables
    assert serve.build_parser is serve_model.build_parser
    assert serve.run is serve_model.run
    assert serve.main is serve_model.main


@pytest.mark.parametrize("build",
                         [serve_model.build_parser, train.build_parser],
                         ids=["serve_model", "train"])
def test_reduced_round_trip(build):
    ap = build()
    assert ap.parse_args([]).reduced is True
    assert ap.parse_args(["--reduced"]).reduced is True
    assert ap.parse_args(["--no-reduced"]).reduced is False


def test_train_full_alias_still_disables():
    ap = train.build_parser()
    assert ap.parse_args(["--full"]).reduced is False
    # later flag wins, both orders parse
    assert ap.parse_args(["--full", "--reduced"]).reduced is True


@pytest.mark.parametrize("build",
                         [serve_model.build_parser, train.build_parser],
                         ids=["serve_model", "train"])
def test_other_flags_survive_the_switch(build):
    ap = build()
    args = ap.parse_args(["--no-reduced", "--batch", "3"])
    assert args.reduced is False and args.batch == 3

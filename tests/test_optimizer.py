"""AdamW + LR schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (OptConfig, adamw_update, global_norm,
                                   init_opt_state, lr_at)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 115, 5)]
    assert lrs[0] < lrs[1] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[2] - 1e-3) < 1e-7               # peak at warmup end
    assert lrs[-1] <= lrs[-2] + 1e-12              # decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9            # floor


def test_global_norm():
    g = {"a": jnp.ones((2, 2)), "b": jnp.ones((5,))}
    assert abs(float(global_norm(g)) - 3.0) < 1e-6


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |update| ~= lr for a fresh state (no decay)."""
    params = {"w": jnp.zeros((4,))}  # ndim<2 -> no weight decay
    opt = init_opt_state(params)
    grads = {"w": jnp.ones((4,)) * 0.5}
    cfg = OptConfig(lr=1e-2, warmup_steps=1, total_steps=10, clip_norm=1e9,
                    weight_decay=0.0)
    new_params, new_opt, m = adamw_update(params, grads, opt, cfg)
    step_lr = float(lr_at(cfg, jnp.asarray(1)))
    np.testing.assert_allclose(np.asarray(new_params["w"]),
                               -step_lr * np.ones(4), rtol=1e-4)
    assert int(new_opt["step"]) == 1


def test_clip_scales_update():
    params = {"w": jnp.zeros((2, 2))}
    opt = init_opt_state(params)
    big = {"w": jnp.full((2, 2), 100.0)}
    cfg = OptConfig(lr=1.0, warmup_steps=0, total_steps=1, clip_norm=1.0,
                    weight_decay=0.0)
    _, _, m = adamw_update(params, big, opt, cfg)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_no_buffer_aliasing_in_opt_state():
    """m and v (and master of fp32 params) must be distinct buffers —
    donation safety (see §Perf notes / train driver)."""
    params = {"a": jnp.zeros((3,)), "b": jnp.zeros((3,))}
    opt = init_opt_state(params)
    bufs = set()
    for leaf in jax.tree.leaves({"m": opt["m"], "v": opt["v"],
                                 "master": opt["master"]}):
        ptr = leaf.unsafe_buffer_pointer()
        assert ptr not in bufs, "aliased optimizer buffers"
        bufs.add(ptr)

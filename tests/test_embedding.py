"""Request-respond embedding lookup: all three methods agree; dedup is
exact; loss math is shard-friendly."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import sweep
from repro.models.embedding import (dedup_ids, embed_lookup, logits_matmul,
                                    softmax_xent)


@settings(max_examples=sweep(15), deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 200), st.integers(8, 64))
def test_lookup_methods_agree(seed, V, T):
    rng = np.random.RandomState(seed)
    table = jnp.asarray(rng.randn(V, 16).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, V, (T,)).astype(np.int32))
    ref = embed_lookup(table, ids, method="gather")
    for m in ["onehot", "rr"]:
        out = embed_lookup(table, ids, method=m)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 300), st.integers(2, 500))
def test_dedup_ids_property(seed, T, V):
    """uniq[inv] == ids and #unique slots == #distinct (Thm 3 request sets)."""
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, V, (T,)).astype(np.int32))
    cap = min(T, V)
    uniq, inv, n_uniq = dedup_ids(ids, cap)
    np.testing.assert_array_equal(np.asarray(uniq)[np.asarray(inv)],
                                  np.asarray(ids))
    assert int(n_uniq) == len(np.unique(np.asarray(ids)))
    assert int(n_uniq) <= cap


def test_zipf_dedup_saves():
    """Under Zipf tokens (real LM data), distinct << total: the RR response
    table is much smaller than the raw request list (the paper's win)."""
    from repro.train.data import DataConfig, SyntheticLM, token_stats
    data = SyntheticLM(DataConfig(vocab=50_000, seq_len=512, global_batch=8,
                                  zipf_a=1.2))
    st_ = token_stats(data.batch_at(0)["tokens"])
    assert st_["dedup_ratio"] < 0.6  # >=40% of requests eliminated


def test_softmax_xent_matches_naive():
    rng = np.random.RandomState(0)
    B, S, V = 3, 8, 50
    logits = jnp.asarray(rng.randn(B, S, V).astype(np.float32))
    labels = jnp.asarray(rng.randint(0, V, (B, S)).astype(np.int32))
    mask = jnp.asarray((rng.rand(B, S) > 0.2).astype(np.float32))
    got = softmax_xent(logits, labels, mask)
    p = jax.nn.log_softmax(logits, -1)
    ref = -(jnp.take_along_axis(p, labels[..., None], -1)[..., 0] * mask
            ).sum() / mask.sum()
    assert abs(float(got) - float(ref)) < 1e-5


def test_logits_shape():
    table = jnp.zeros((64, 8))
    h = jnp.zeros((2, 3, 8))
    assert logits_matmul(h, table).shape == (2, 3, 64)
    assert logits_matmul(h, table).dtype == jnp.float32

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.segment_combine.kernel import segment_combine_blocks
from repro.kernels.segment_combine.ops import (pack_edges, pack_values,
                                               segment_combine)
from repro.kernels.segment_combine.ref import segment_combine_blocks_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_chunked


# ---------------------------------------------------------------------------
# segment_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("nb,eb,n_blocks", [(128, 256, 3), (256, 128, 2),
                                            (64, 512, 5)])
def test_segment_combine_blocks_vs_ref(op, nb, eb, n_blocks):
    rng = np.random.RandomState(0)
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randn(n_blocks, eb).astype(np.float32)
    out = segment_combine_blocks(jnp.asarray(vals), jnp.asarray(idx), op, nb)
    ref = segment_combine_blocks_ref(jnp.asarray(vals), jnp.asarray(idx),
                                     op, nb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_segment_combine_int32_round_trip(op):
    """pack_values/combine must preserve integer dtypes exactly: ids above
    2^24 (unrepresentable in float32) survive the packed combine.  The old
    float32 coercion in pack_values returned 16_777_216 for both."""
    rng = np.random.RandomState(7)
    N, E = 300, 1200
    dst = rng.randint(0, N, E)
    vals = rng.randint(2 ** 24 - 2, 2 ** 24 + 50, E).astype(np.int32)
    order, idxl = pack_edges(dst, N, nb=128, eb_align=128)
    pv = pack_values(vals, order, idxl, op)
    assert pv.dtype == np.int32, "pack_values must preserve the dtype"
    out = np.asarray(segment_combine(jnp.asarray(pv), jnp.asarray(idxl),
                                     op, 128, N))
    assert out.dtype == np.int32
    red = {"sum": np.add, "min": np.minimum, "max": np.maximum}[op]
    iinfo = np.iinfo(np.int32)
    ident = {"sum": 0, "min": iinfo.max, "max": iinfo.min}[op]
    ref = np.full(N, ident, np.int32)
    red.at(ref, dst, vals)
    np.testing.assert_array_equal(out, ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["sum", "min", "max"]),
       st.integers(10, 2000), st.integers(50, 900))
def test_segment_combine_end_to_end(seed, op, E, N):
    rng = np.random.RandomState(seed)
    dst = rng.randint(0, N, E)
    vals = rng.randn(E).astype(np.float32)
    order, idxl = pack_edges(dst, N, nb=128, eb_align=128)
    pv = pack_values(vals, order, idxl, op)
    out = np.asarray(segment_combine(jnp.asarray(pv), jnp.asarray(idxl),
                                     op, 128, N))
    red = {"sum": np.add, "min": np.minimum, "max": np.maximum}[op]
    ref = np.full(N, {"sum": 0., "min": 3e38, "max": -3e38}[op], np.float32)
    red.at(ref, dst, vals)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

# tier-1 keeps one causal and one non-causal cell; the rest nightly
@pytest.mark.parametrize("B,S,H,K,hd,causal,window,dtype", [
    (2, 256, 4, 2, 32, True, 0, jnp.float32),
    (2, 128, 8, 2, 16, False, 0, jnp.float32),
    pytest.param(1, 512, 4, 4, 64, True, 128, jnp.float32,
                 marks=pytest.mark.slow),
    pytest.param(1, 256, 4, 1, 32, True, 0, jnp.bfloat16,
                 marks=pytest.mark.slow),   # MQA, bf16
    pytest.param(1, 128, 2, 2, 128, True, 64, jnp.float32,
                 marks=pytest.mark.slow),   # hd = lane width
])
def test_flash_attention_vs_ref(B, S, H, K, hd, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    o1 = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    o2 = flash_attention(q, k, v, causal=causal, window=window,
                         use_kernel=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


def test_flash_attention_matches_model_attention():
    """Kernel == the model's chunked_attention == plain attention."""
    from repro.models.layers import AttnSpec, attention, chunked_attention
    key = jax.random.PRNGKey(1)
    B, S, H, K, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    spec = AttnSpec(n_heads=H, n_kv_heads=K, head_dim=hd, causal=True,
                    window=0, q_chunk=32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = attention(q, k, v, spec, pos, pos)
    c = chunked_attention(q, k, v, spec, pos, pos)
    f = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    assert float(jnp.abs(a - c).max()) < 1e-5
    assert float(jnp.abs(a - f).max()) < 1e-5


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 256, 4, 16, 32, 64),
    pytest.param(1, 128, 2, 64, 128, 128,
                 marks=pytest.mark.slow),   # full-size head dims
    pytest.param(3, 64, 8, 8, 16, 16, marks=pytest.mark.slow),
])
def test_ssd_kernel_vs_recurrent(b, s, h, p, n, chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1 = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y2 = ssd_scan(x, dt, A, B, C, use_kernel=False)
    assert float(jnp.abs(y1 - y2).max()) < 5e-3


@pytest.mark.slow
def test_ssd_model_impl_matches_kernel():
    key = jax.random.PRNGKey(3)
    b, s, h, p, n, chunk = 2, 128, 4, 16, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    ym, _ = ssd_chunked(x, dt, A, B, C, chunk)
    yk = ssd_scan(x, dt, A, B, C, chunk=chunk)
    assert float(jnp.abs(ym - yk).max()) < 5e-3


@pytest.mark.slow
def test_ssd_decode_matches_scan():
    """The O(1) decode recurrence continues the chunked scan exactly."""
    from repro.models.ssm import ssd_decode_step
    key = jax.random.PRNGKey(4)
    b, s, h, p, n = 1, 64, 2, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s + 1, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s + 1, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s + 1, 1, n))
    C = jax.random.normal(ks[4], (b, s + 1, 1, n))
    y_full, _ = ssd_chunked(x, dt.astype(jnp.float32), A, B, C, chunk=s + 1)
    _, state = ssd_chunked(x[:, :s], dt[:, :s].astype(jnp.float32), A,
                           B[:, :s], C[:, :s], chunk=s)
    rep = h // 1
    y1, _ = ssd_decode_step(state, x[:, s], dt[:, s].astype(jnp.float32), A,
                            B[:, s], C[:, s])
    assert float(jnp.abs(y1 - y_full[:, s]).max()) < 1e-3

"""Vector (lanes, F) message payloads: kernel/plan/channel parity with
the scalar path and with per-feature references.

The refactor's contract is structural: a scalar input evaluates the exact
original expressions, so F=1 must be BITWISE identical to the scalar
path, and an F-block result must equal F independent scalar runs (modulo
nothing — the combine order per feature is unchanged)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channels
from repro.core import plan as planlib
from repro.graph import generators as gen
from repro.graph.structs import partition
from repro.kernels.segment_combine.kernel import sentinels
from repro.kernels.segment_combine.ops import pack_edges, pack_values
from repro.kernels.segment_combine.ref import segment_combine_blocks_ref
from repro.kernels.segment_combine.kernel import segment_combine_blocks


def _pg(layout="csr", n=180, M=8, tau=8):
    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    return partition(g, M, tau=tau, seed=0, layout=layout)


# ---------------------------------------------------------------------------
# kernel: (n_blocks, eb, F) combine vs ref and vs per-feature scalar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["sum", "min", "max"])
@pytest.mark.parametrize("F", [1, 8, 32, 130])
def test_vector_blocks_vs_ref(op, F):
    # F=130 exceeds one 128-lane feature tile -> exercises the tile loop
    rng = np.random.RandomState(0)
    nb, eb, n_blocks = 128, 256, 3
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randn(n_blocks, eb, F).astype(np.float32)
    out = segment_combine_blocks(jnp.asarray(vals), jnp.asarray(idx), op, nb)
    ref = segment_combine_blocks_ref(jnp.asarray(vals), jnp.asarray(idx),
                                     op, nb)
    assert out.shape == (n_blocks, nb, F)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_vector_blocks_match_per_feature_scalar(op):
    rng = np.random.RandomState(1)
    nb, eb, n_blocks, F = 64, 128, 2, 5
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randn(n_blocks, eb, F).astype(np.float32)
    out = np.asarray(segment_combine_blocks(jnp.asarray(vals),
                                            jnp.asarray(idx), op, nb))
    for f in range(F):
        col = np.asarray(segment_combine_blocks(
            jnp.asarray(vals[:, :, f]), jnp.asarray(idx), op, nb))
        np.testing.assert_array_equal(out[:, :, f], col)


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_f1_bitwise_identical_to_scalar(op):
    rng = np.random.RandomState(2)
    nb, eb, n_blocks = 128, 256, 2
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randn(n_blocks, eb).astype(np.float32)
    scalar = np.asarray(segment_combine_blocks(jnp.asarray(vals),
                                               jnp.asarray(idx), op, nb))
    vec = np.asarray(segment_combine_blocks(jnp.asarray(vals[..., None]),
                                            jnp.asarray(idx), op, nb))
    np.testing.assert_array_equal(scalar, vec[:, :, 0])


@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_int_vector_blocks_exact(op):
    rng = np.random.RandomState(3)
    nb, eb, n_blocks, F = 64, 128, 2, 3
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randint(-1000, 1000, (n_blocks, eb, F)).astype(np.int32)
    out = segment_combine_blocks(jnp.asarray(vals), jnp.asarray(idx), op, nb)
    ref = segment_combine_blocks_ref(jnp.asarray(vals), jnp.asarray(idx),
                                     op, nb)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# half precision: sentinel fallback + signed zeros / infinities
# ---------------------------------------------------------------------------

def test_sentinels_fit_in_dtype():
    """float16's finfo.max (65504) is far below the float32 sentinel
    (3e38): the kernel must fall back to the dtype's own bounds or the
    min/max identity becomes inf and the no-contribution remap breaks."""
    for dt in (jnp.float16, jnp.bfloat16, jnp.float32):
        neg, pos = sentinels(dt)
        assert np.isfinite(np.asarray(jnp.asarray(pos, dt), np.float64))
        assert np.isfinite(np.asarray(jnp.asarray(neg, dt), np.float64))
    assert sentinels(jnp.float16) == (-65504.0, 65504.0)


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
@pytest.mark.parametrize("op", ["sum", "min", "max"])
def test_half_precision_zeros_and_inf(dtype, op):
    """Regression: combining +-0.0 (all ops) and +-inf (min/max) in half
    precision.  The pallas kernel must agree with the jnp scatter
    reference (inf saturates to the dtype sentinel under min/max by
    design — the same clamp the reference's identity init applies; the
    sum path is a one-hot contraction in BOTH implementations, where a
    0*inf product is NaN, so infs stay out of the sum leg)."""
    rng = np.random.RandomState(4)
    nb, eb, n_blocks, F = 64, 128, 2, 4
    idx = rng.randint(-1, nb, (n_blocks, eb)).astype(np.int32)
    vals = rng.randn(n_blocks, eb, F).astype(np.float32)
    # sprinkle the awkward values everywhere
    if op == "sum":
        # saturation extremes are out too: the reference's stepwise half
        # rounding diverges from the kernel's fp32 accumulation there
        special = np.array([0.0, -0.0, 1.5, -1.5], np.float32)
    else:
        special = np.array([0.0, -0.0, np.inf, -np.inf], np.float32)
    pick = rng.randint(0, 4, vals.shape)
    use = rng.rand(*vals.shape) < 0.3
    vals = np.where(use, special[pick], vals)
    v = jnp.asarray(vals, dtype)
    out = segment_combine_blocks(v, jnp.asarray(idx), op, nb)
    ref = segment_combine_blocks_ref(v, jnp.asarray(idx), op, nb)
    assert out.dtype == dtype
    o32 = np.asarray(out, np.float32)
    r32 = np.asarray(ref, np.float32)
    if op == "sum":
        # half sums accumulate in fp32 inside the kernel; the reference
        # accumulates in the half dtype — allow half-precision slack
        np.testing.assert_allclose(o32, r32, rtol=2e-2, atol=2e-2)
        assert np.isfinite(o32).all()
    else:
        np.testing.assert_array_equal(o32, r32)


@pytest.mark.parametrize("mode", ["ref", "pallas"])
@pytest.mark.parametrize("op", ["min", "max"])
def test_half_precision_identity_remap(op, mode):
    """The plan-layer sentinel remap in f16: rows with NO contributing
    edge must come back as the CHANNEL identity (+-inf), not the kernel's
    finite f16 sentinel (+-65504) — the regression the sentinel fallback
    fixes: with the canonical 3e38 thresholds (inf in f16) the remap
    comparison could never fire."""
    rng = np.random.RandomState(5)
    N, E = 200, 600
    nb = 64
    dst = rng.randint(0, N // 2, E)  # upper half: no contributions
    vals = (rng.randn(E).astype(np.float16)).astype(np.float16)
    order, idxl = pack_edges(dst, N, nb=nb, eb_align=128)
    pv = pack_values(vals, order, idxl, op)
    old = planlib.kernel_mode()
    planlib.set_kernel_mode(mode)
    try:
        blocks = planlib._combine_rows(jnp.asarray(pv), jnp.asarray(idxl),
                                       op, nb)
    finally:
        planlib.set_kernel_mode(old)
    out = np.asarray(blocks).reshape(-1)[:N]
    ident = np.asarray(planlib.identity_of(op, jnp.float16), np.float16)
    assert np.isinf(ident)
    assert (out[N // 2:] == ident).all()
    red = np.minimum if op == "min" else np.maximum
    ref = np.full(N, ident, np.float16)
    red.at(ref, dst, vals)
    np.testing.assert_array_equal(out[: N // 2], ref[: N // 2])


# ---------------------------------------------------------------------------
# plan + channels: vector payloads vs per-feature scalar runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["padded", "csr"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
@pytest.mark.parametrize("op", ["sum", "min"])
def test_broadcast_vector_matches_per_feature(layout, backend, op):
    F = 3
    pg = _pg(layout)
    rng = np.random.RandomState(6)
    vals = rng.randn(pg.M, pg.n_loc, F).astype(np.float32)
    act = rng.rand(pg.M, pg.n_loc) > 0.3
    out, stats = channels.broadcast(pg, jnp.asarray(vals), jnp.asarray(act),
                                    op, relay="mul_w", backend=backend)
    assert out.shape == (pg.M, pg.n_loc, F)
    for f in range(F):
        ref, rs = channels.broadcast(pg, jnp.asarray(vals[:, :, f]),
                                     jnp.asarray(act), op, relay="mul_w",
                                     backend=backend)
        np.testing.assert_array_equal(np.asarray(out[:, :, f]),
                                      np.asarray(ref))
        # activity (and thus message accounting) is per LANE, not per
        # feature: the vector join sends one (F,) block per active lane
        for k in ("msgs_total", "msgs_combined", "msgs_mirror"):
            if k in rs:
                np.testing.assert_array_equal(np.asarray(stats[k]),
                                              np.asarray(rs[k]))


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_broadcast_f1_bitwise_identical(backend):
    pg = _pg("csr")
    rng = np.random.RandomState(7)
    vals = rng.randn(pg.M, pg.n_loc).astype(np.float32)
    act = rng.rand(pg.M, pg.n_loc) > 0.3
    s_out, _ = channels.broadcast(pg, jnp.asarray(vals), jnp.asarray(act),
                                  "min", backend=backend)
    v_out, _ = channels.broadcast(pg, jnp.asarray(vals)[..., None],
                                  jnp.asarray(act), "min", backend=backend)
    np.testing.assert_array_equal(np.asarray(s_out),
                                  np.asarray(v_out)[:, :, 0])


def test_gather_vector_matches_per_feature():
    pg = _pg("csr")
    rng = np.random.RandomState(8)
    F, R = 4, 11
    vals = rng.randn(pg.M, pg.n_loc, F).astype(np.float32)
    targets = rng.randint(0, pg.n_pad, (pg.M, R)).astype(np.int32)
    tmask = rng.rand(pg.M, R) > 0.25
    out, _ = channels.gather(pg, jnp.asarray(vals), jnp.asarray(targets),
                             jnp.asarray(tmask))
    assert out.shape == (pg.M, R, F)
    for f in range(F):
        ref, _ = channels.gather(pg, jnp.asarray(vals[:, :, f]),
                                 jnp.asarray(targets), jnp.asarray(tmask))
        np.testing.assert_array_equal(np.asarray(out[:, :, f]),
                                      np.asarray(ref))


def test_node_embedding_fetch_vector_rows():
    from repro.models.embedding import (node_embedding_fetch,
                                        node_embedding_init)
    pg = _pg("csr")
    F, R = 6, 9
    tab = node_embedding_init(pg, F, seed=3)
    assert tab.shape == (pg.M, pg.n_loc, F)
    # padding slots are zero rows
    flat = np.asarray(tab).reshape(pg.n_pad, F)
    valid = np.zeros(pg.n_pad, bool)
    valid[np.asarray(pg.perm)] = True
    assert (flat[~valid] == 0).all()
    rng = np.random.RandomState(9)
    ids = rng.randint(0, pg.n_pad, (pg.M, R)).astype(np.int32)
    mask = rng.rand(pg.M, R) > 0.2
    got, _ = node_embedding_fetch(pg, tab, jnp.asarray(ids),
                                  jnp.asarray(mask))
    ref = flat[ids] * mask[:, :, None]
    np.testing.assert_array_equal(np.asarray(got), ref.astype(np.float32))

"""Sharding-rule regression tests: lower + compile the real train/serve
steps on a small fake mesh (subprocess, 8 devices) and assert batch
sharding survives the embedding (the §Perf iteration-1 defect class)."""
import subprocess

import pytest
import sys
import textwrap

import pytest


def _run(code: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, cwd=".",
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


@pytest.mark.slow  # 8-device subprocess dry-run: nightly
def test_train_step_lowers_sharded():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, dataclasses, re
        from repro.configs.base import get_config
        from repro.launch import shardings as sh
        from repro.models.transformer import ModelContext
        from repro.train.train_step import (StepConfig, abstract_train_state,
                                            make_train_step)
        from repro.models import model_zoo as zoo
        from repro.configs.base import ShapeConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_config("tinyllama_1_1b").reduced(), vocab=256)
        ctx = ModelContext(mesh=mesh, dp_axes=("data",), remat="full",
                           q_chunk=16, scan_layers=True)
        state = abstract_train_state(cfg, 4, jnp.bfloat16)
        sspecs = sh.train_state_specs(cfg, mesh, state)
        shape = ShapeConfig("t", 32, 8, "train")
        bspecs = sh.batch_specs(cfg, shape, mesh)
        step = make_train_step(cfg, ctx, StepConfig())
        inputs = zoo.input_specs(cfg, shape)
        lowered = jax.jit(step, in_shardings=(sh.named(mesh, sspecs),
                                              sh.named(mesh, bspecs)),
                          donate_argnums=(0,)).lower(state, inputs)
        compiled = lowered.compile()
        txt = compiled.as_text()
        assert "all-reduce" in txt or "all-gather" in txt
        # batch stays sharded: no full-batch (8, 32, d_model) activations
        # should be all-reduced; 4/chip is the sharded size
        assert not re.search(r"f32\\[8,32,64\\][^)]*all-reduce", txt)
        print("train lower OK")
    """)
    assert "train lower OK" in out


@pytest.mark.slow  # 8-device subprocess dry-run: nightly
def test_decode_step_lowers_with_cache_specs():
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import NamedSharding
        from repro.configs.base import get_config, ShapeConfig
        from repro.launch import shardings as sh
        from repro.models import model_zoo as zoo
        from repro.models.transformer import ModelContext
        from repro.train.train_step import make_decode_step
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_config("gemma3_4b").reduced(), vocab=256)
        ctx = ModelContext(mesh=mesh, dp_axes=("data",), q_chunk=16,
                           scan_layers=True)
        shape = ShapeConfig("d", 64, 8, "decode")
        params = zoo.abstract_params(cfg, 4, jnp.bfloat16)
        pspecs = sh.param_specs(cfg, mesh, params)
        cache = zoo.build_cache(cfg, 8, 64, ctx, abstract=True)
        cspecs = sh.cache_specs(cfg, shape, mesh, cache)
        token = zoo.input_specs(cfg, shape)["token"]
        tspec = sh.batch_specs(cfg, shape, mesh)["token"]
        fn = make_decode_step(cfg, ctx)
        compiled = jax.jit(
            fn, in_shardings=(sh.named(mesh, pspecs),
                              NamedSharding(mesh, tspec),
                              sh.named(mesh, cspecs))
        ).lower(params, token, cache).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):   # jaxlib < 0.5 returns [dict]
            ca = ca[0]
        print("decode lower OK", int(ca["flops"]))
    """)
    assert "decode lower OK" in out


@pytest.mark.slow  # 8-device subprocess dry-run: nightly
def test_collective_parser():
    from repro.launch.hlo_stats import collective_bytes
    hlo = """
      %p = f32[16,8]{1,0} parameter(0)
      %ar = f32[16,8]{1,0} all-reduce(%p), replica_groups={}
      %ag = f32[64,8]{1,0} all-gather(%p), dimensions={0}
      %done = f32[16,8]{1,0} all-reduce-done(%ar)
    """
    out = collective_bytes(hlo)
    assert out["all-reduce"]["bytes"] == 16 * 8 * 4
    assert out["all-reduce"]["count"] == 1  # -done not double counted
    assert out["all-gather"]["bytes"] == 16 * 8 * 4  # operand, not output

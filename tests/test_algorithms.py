"""Algorithm correctness vs numpy oracles (+ hypothesis randomization)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import sweep
from repro.algorithms.attr_bcast import attribute_broadcast
from repro.algorithms.hashmin import hashmin
from repro.algorithms.msf import msf
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.sv import sv
from repro.graph import generators as gen
from repro.graph.structs import partition


def _check_cc(g, pg, labels, cc_oracle):
    flat = np.asarray(labels).reshape(-1)
    mine = flat[pg.perm]  # per original vertex
    oc = cc_oracle(g.n, g.src, g.dst)
    groups = {}
    for v in range(g.n):
        groups.setdefault(oc[v], set()).add(int(mine[v]))
    assert all(len(s) == 1 for s in groups.values())
    labs = [next(iter(s)) for s in groups.values()]
    assert len(set(labs)) == len(labs)


@settings(max_examples=sweep(6), deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 8]),
       st.sampled_from(["powerlaw", "two_cliques", "chain"]))
def test_hashmin_cc(seed, M, kind, ):
    if kind == "powerlaw":
        g = gen.powerlaw(400, avg_deg=5, seed=seed).symmetrized()
    elif kind == "two_cliques":
        g = gen.two_cliques(20)
    else:
        g = gen.chain(64)
    pg = partition(g, M, tau=16, seed=seed % 7)
    labels, stats, n = hashmin(pg)
    from conftest import union_find_cc
    _check_cc(g, pg, labels, union_find_cc)


def _check_sv_cc(seed, M):
    g = gen.powerlaw(400, avg_deg=5, seed=seed).symmetrized()
    pg = partition(g, M, tau=None, seed=seed % 5)
    labels, stats, n = sv(pg)
    from conftest import union_find_cc
    _check_cc(g, pg, labels, union_find_cc)
    # request-respond strictly reduces messages in S-V (Fig. 13)
    assert int(stats["msgs_rr"]) < int(stats["msgs_basic"])


def test_sv_cc():
    """One-seed oracle check in tier-1; the multi-seed sweep is nightly."""
    _check_sv_cc(11, 8)


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 8]))
def test_sv_cc_sweep(seed, M):
    _check_sv_cc(seed, M)


def test_sv_logarithmic_rounds():
    """S-V on a long chain converges in O(log n), not O(diameter)."""
    g = gen.chain(1024)
    pg = partition(g, 8, tau=None, seed=0)
    _, _, n_rounds = sv(pg)
    assert int(n_rounds) <= 25  # ~log2(1024) + slack; diameter is 1023
    _, _, n_hm = hashmin(pg)
    assert int(n_hm) > int(n_rounds)  # Hash-Min needs O(diameter)


def test_pagerank_matches_power_iteration():
    g = gen.powerlaw(800, avg_deg=7, seed=2).symmetrized()
    pg = partition(g, 8, tau=32, seed=1)
    pr, _, _ = pagerank(pg, n_iters=20, tol=1e-12)
    mine = np.asarray(pr).reshape(-1)[pg.perm]
    deg = np.bincount(g.src, minlength=g.n)
    x = np.full(g.n, 1.0 / g.n)
    for _ in range(20):
        contrib = np.where(deg > 0, x / np.maximum(deg, 1), 0.0)
        inbox = np.zeros(g.n)
        np.add.at(inbox, g.dst, contrib[g.src])
        x = 0.15 / g.n + 0.85 * inbox
    np.testing.assert_allclose(mine, x, rtol=1e-4, atol=1e-7)


def test_pagerank_mirroring_same_result():
    g = gen.powerlaw(600, avg_deg=8, seed=4, alpha=1.8).symmetrized()
    pg = partition(g, 8, tau=10, seed=0)
    pr1, s1, _ = pagerank(pg, n_iters=10, tol=1e-12, use_mirroring=True)
    pr2, s2, _ = pagerank(pg, n_iters=10, tol=1e-12, use_mirroring=False)
    np.testing.assert_allclose(np.asarray(pr1), np.asarray(pr2),
                               rtol=1e-5, atol=1e-9)
    assert int(s1["msgs_total"]) < int(s2["msgs_combined"])


def test_sssp_matches_bellman_ford():
    g = gen.grid_road(20, weighted=True)
    pg = partition(g, 8, tau=None, seed=0)
    src_new = int(pg.perm[0])
    dist, _, _ = sssp(pg, src_new)
    mine = np.asarray(dist).reshape(-1)[pg.perm]
    dd = np.full(g.n, np.inf)
    dd[0] = 0.0
    for _ in range(500):
        nd = dd.copy()
        np.minimum.at(nd, g.dst, dd[g.src] + g.weight)
        if np.allclose(nd, dd):
            break
        dd = nd
    np.testing.assert_allclose(mine, dd, rtol=1e-5, atol=1e-5)


def test_sssp_relay_with_mirroring():
    """relay() adds edge weights at the mirror: same result either channel."""
    g = gen.powerlaw(400, avg_deg=8, seed=6, weighted=True).symmetrized()
    pg = partition(g, 8, tau=8, seed=0)
    s = int(pg.perm[0])
    d1, _, _ = sssp(pg, s, use_mirroring=True)
    d2, _, _ = sssp(pg, s, use_mirroring=False)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


def _check_msf_kruskal(seed):
    g = gen.powerlaw(300, avg_deg=5, seed=seed, weighted=True).symmetrized()
    pg = partition(g, 8, tau=None, seed=seed % 3)
    (res, stats, n) = msf(pg)
    _, tw, ne = res
    from conftest import kruskal_msf
    tw_o, ne_o = kruskal_msf(g.n, g.src, g.dst, g.weight)
    assert int(ne) == ne_o
    assert abs(float(tw) - tw_o) < 1e-3
    assert int(stats["msgs_rr"]) < int(stats["msgs_basic"])


def test_msf_matches_kruskal():
    """One-seed oracle check in tier-1; the multi-seed sweep is nightly."""
    _check_msf_kruskal(7)


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_msf_matches_kruskal_sweep(seed):
    _check_msf_kruskal(seed)


def test_attr_broadcast_annotates_adjacency():
    g = gen.powerlaw(500, avg_deg=6, seed=1).symmetrized()
    pg = partition(g, 8, tau=None, seed=0)
    attr = jnp.arange(pg.n_pad, dtype=jnp.float32).reshape(pg.M, pg.n_loc) * 2
    out, stats = attribute_broadcast(pg, attr)
    o, d, m = np.asarray(out), np.asarray(pg.all_dst), np.asarray(pg.all_mask)
    np.testing.assert_allclose(o[m], 2.0 * d[m])
    assert int(stats["msgs_rr"]) <= int(stats["msgs_basic"])

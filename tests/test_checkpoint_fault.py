"""Checkpoint/restart, preemption continuity, elastic re-mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import generators as gen
from repro.graph.structs import partition
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault import repartition, straggler_report


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": [jnp.zeros((4,), jnp.int32), {"c": jnp.ones(())}]}
    ckpt.save(str(tmp_path), 7, tree)
    out, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_latest_and_prune(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_4", "step_5"]


def test_restore_or_init(tmp_path):
    init = lambda: {"w": jnp.zeros((3,))}
    state, step = ckpt.restore_or_init(str(tmp_path), init)
    assert step == 0
    state = {"w": jnp.ones((3,)) * 9}
    ckpt.save(str(tmp_path), 42, state)
    state2, step2 = ckpt.restore_or_init(str(tmp_path), init)
    assert step2 == 42
    np.testing.assert_array_equal(np.asarray(state2["w"]), 9.0 * np.ones(3))


@pytest.mark.slow  # multi-restart BSP loop: nightly
def test_preemption_continuity(tmp_path):
    """Kill training mid-run; the resumed loss curve equals the straight
    run bit-for-bit (deterministic data + checkpointed state)."""
    from repro.launch.train import run

    d1 = str(tmp_path / "a")
    straight = run("tinyllama_1_1b", True, 12, 2, 16, d1, ckpt_every=0,
                   log_every=100)
    d2 = str(tmp_path / "b")
    first = run("tinyllama_1_1b", True, 6, 2, 16, d2, ckpt_every=6,
                log_every=100)
    resumed = run("tinyllama_1_1b", True, 12, 2, 16, d2, ckpt_every=6,
                  log_every=100)
    with_kill = first + resumed
    np.testing.assert_allclose(with_kill, straight, rtol=2e-4, atol=1e-5)


def test_data_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    d = SyntheticLM(cfg)
    b1 = d.batch_at(5)
    b2 = d.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    shards = [d.batch_at(5, shard=i, n_shards=4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), b1["tokens"])


def test_elastic_repartition_preserves_state():
    """BSP state survives an elastic M=8 -> M=4 re-mesh by vertex id."""
    g = gen.powerlaw(300, avg_deg=5, seed=1).symmetrized()
    pg8 = partition(g, 8, tau=16, seed=0)
    state = jnp.asarray(
        np.random.RandomState(0).randn(pg8.M, pg8.n_loc).astype(np.float32))
    pg4, state4 = repartition(g, np.asarray(state), pg8, 4, tau=16, seed=0)
    # value of every original vertex is preserved
    v8 = np.asarray(state).reshape(-1)[pg8.perm]
    v4 = np.asarray(state4).reshape(-1)[pg4.perm]
    np.testing.assert_allclose(v8, v4)
    # and the computation continues correctly on the new mesh
    from repro.algorithms.hashmin import hashmin
    l4, _, _ = hashmin(pg4)
    l8, _, _ = hashmin(pg8)
    np.testing.assert_array_equal(
        np.asarray(l4).reshape(-1)[pg4.perm],
        np.asarray(l8).reshape(-1)[pg8.perm])


def test_straggler_report():
    rep = straggler_report(np.array([10, 10, 10, 70]))
    assert rep["max_over_mean"] == pytest.approx(2.8)
    assert rep["cv"] > 0.9
    flat = straggler_report(np.ones(8))
    assert flat["max_over_mean"] == pytest.approx(1.0)
    assert flat["gini"] == pytest.approx(0.0, abs=1e-9)

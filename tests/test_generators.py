"""Property coverage for ``graph/generators.py`` — previously the only
untested module in graph/.

Three families of invariants:

* **power-law degree tail** — the Chung-Lu generator must actually be
  skewed: the hottest vertex carries many times its fair share, heavier
  at smaller alpha, while ``erdos``/``grid_road`` stay flat;
* **seed determinism** — same seed bitwise-same graph, different seed a
  different one (the conformance matrix and every benchmark depend on
  partition(seed) reproducibility all the way down to the generator);
* **symmetrization / dedup invariants** — no self loops, no duplicate
  directed pairs, every edge's reverse present, and undirected weights
  canonicalized so w(a, b) == w(b, a).
"""
import numpy as np
import pytest

from repro.graph import generators as gen


def _pair_key(g):
    return g.src.astype(np.int64) * g.n + g.dst


# -- power-law degree tail -------------------------------------------------

def test_powerlaw_degree_tail_is_skewed():
    g = gen.powerlaw(5000, avg_deg=8, seed=0, alpha=1.8)
    deg = g.out_degrees()
    mean = deg.mean()
    # a real heavy tail: the hub carries >> its fair share...
    assert deg.max() > 20 * mean
    # ...while most vertices sit at or below the mean
    assert (deg <= mean).sum() > 0.5 * g.n


def test_powerlaw_tail_heavier_at_smaller_alpha():
    tails = []
    for alpha in (1.5, 2.5):
        g = gen.powerlaw(5000, avg_deg=8, seed=1, alpha=alpha)
        deg = g.out_degrees()
        tails.append(deg.max() / deg.mean())
    assert tails[0] > tails[1]


def test_flat_generators_have_no_tail():
    deg = gen.erdos(2000, avg_deg=10, seed=0).out_degrees()
    assert deg.max() < 5 * deg.mean()
    deg = gen.grid_road(30).out_degrees()
    assert deg.max() <= 4


# -- seed determinism ------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda s: gen.powerlaw(800, avg_deg=6, seed=s, alpha=1.7,
                           weighted=True),
    lambda s: gen.erdos(500, avg_deg=8, seed=s, weighted=True),
])
def test_seed_determinism(make):
    a, b, c = make(7), make(7), make(8)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    np.testing.assert_array_equal(a.weight, b.weight)
    assert (a.m != c.m) or not np.array_equal(a.src, c.src)


# -- dedup / symmetrization invariants -------------------------------------

@pytest.mark.parametrize("g", [
    gen.powerlaw(600, avg_deg=6, seed=2, alpha=1.6),
    gen.erdos(400, avg_deg=8, seed=3),
], ids=["powerlaw", "erdos"])
def test_dedup_no_self_loops_no_duplicates(g):
    assert (g.src != g.dst).all()
    key = _pair_key(g)
    assert len(np.unique(key)) == g.m


@pytest.mark.parametrize("g", [
    gen.powerlaw(600, avg_deg=6, seed=2, alpha=1.6, weighted=True),
    gen.chain(40),
    gen.star(50),
    gen.two_cliques(8),
], ids=["powerlaw", "chain", "star", "two_cliques"])
def test_symmetrized_has_both_directions(g):
    s = g.symmetrized()
    key = set(_pair_key(s).tolist())
    rev = set((s.dst.astype(np.int64) * s.n + s.src).tolist())
    assert key == rev
    assert (s.src != s.dst).all()
    assert len(key) == s.m


def test_symmetrized_weights_are_undirected():
    g = gen.powerlaw(500, avg_deg=6, seed=4, alpha=1.7,
                     weighted=True).symmetrized()
    w = {}
    for a, b, x in zip(g.src.tolist(), g.dst.tolist(),
                       g.weight.tolist()):
        w[(a, b)] = x
    for (a, b), x in w.items():
        assert w[(b, a)] == x


def test_adversarial_shapes():
    g = gen.chain(10)
    deg = np.bincount(np.concatenate([g.src]), minlength=g.n)
    assert deg[0] == deg[-1] == 1 and (deg[1:-1] == 2).all()
    g = gen.star(10)
    deg = g.out_degrees()
    assert deg[0] == 9 and (deg[1:] == 1).all()
    g = gen.two_cliques(5)
    # 2 * k*(k-1) intra-clique directed edges + the 2-way bridge
    assert g.m == 2 * 5 * 4 + 2
    assert g.n == 10

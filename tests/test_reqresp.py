"""Direct property tests for Ch_req (request-respond) — Theorem 3.

The channel previously had only indirect coverage through sv/msf; these
pin its contract: the 2*M*distinct-targets bound, dedup idempotence,
dedup=False value equality, and padded/flat stats agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import sweep
from repro.core.channels import _dedup_row, rr_gather, rr_gather_flat


def _case(seed, M=5, n_loc=40, R=60, hot_frac=0.4):
    rng = np.random.RandomState(seed % (2 ** 31))
    vals = rng.randn(M, n_loc).astype(np.float32)
    targets = rng.randint(0, M * n_loc, (M, R)).astype(np.int32)
    hot = rng.randint(0, M * n_loc)
    targets[:, : int(R * hot_frac)] = hot          # the S-V skew pattern
    mask = rng.rand(M, R) > 0.25
    return (jnp.asarray(vals), jnp.asarray(targets), jnp.asarray(mask),
            M, n_loc, R)


@settings(max_examples=sweep(15), deadline=None)
@given(st.integers(0, 10_000))
def test_thm3_bound_two_M_per_distinct_target(seed):
    """msgs_rr <= 2 * M * (#distinct requested targets): each distinct
    target is requested at most once per worker, and every request costs
    a request + a response message."""
    vals, targets, mask, M, n_loc, R = _case(seed)
    _, stats = rr_gather(vals, targets, mask, M, n_loc)
    distinct = len(np.unique(np.asarray(targets)[np.asarray(mask)]))
    assert int(stats["msgs_rr"]) <= 2 * M * distinct
    # and the paper's per-target form: 2 * sum_t min(M, l_t)
    t_np, m_np = np.asarray(targets), np.asarray(mask)
    bound = 2 * sum(min(M, int((t_np[m_np] == t).sum()))
                    for t in np.unique(t_np[m_np]))
    assert int(stats["msgs_rr"]) <= bound


@settings(max_examples=sweep(15), deadline=None)
@given(st.integers(0, 10_000))
def test_dedup_row_idempotent(seed):
    """Deduplicating an already-deduplicated request list is a no-op."""
    rng = np.random.RandomState(seed % (2 ** 31))
    n_pad = 64
    t = jnp.asarray(rng.randint(0, n_pad, 30).astype(np.int32))
    u1, _ = _dedup_row(t, n_pad)
    u2, _ = _dedup_row(u1, n_pad)
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000))
def test_dedup_gains_nothing_on_unique_targets(seed):
    """When every worker's masked targets are already distinct,
    request-respond degenerates to the basic channel count."""
    rng = np.random.RandomState(seed % (2 ** 31))
    M, n_loc, R = 4, 50, 30
    vals = jnp.asarray(rng.randn(M, n_loc).astype(np.float32))
    targets = np.stack([rng.choice(M * n_loc, R, replace=False)
                        for _ in range(M)]).astype(np.int32)
    mask = rng.rand(M, R) > 0.3
    _, stats = rr_gather(vals, jnp.asarray(targets), jnp.asarray(mask),
                         M, n_loc)
    assert int(stats["msgs_rr"]) == int(stats["msgs_basic"])


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000))
def test_dedup_false_same_values_basic_counts(seed):
    """dedup only changes the message accounting, never the values."""
    vals, targets, mask, M, n_loc, R = _case(seed)
    out_d, s_d = rr_gather(vals, targets, mask, M, n_loc, dedup=True)
    out_n, s_n = rr_gather(vals, targets, mask, M, n_loc, dedup=False)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_n))
    assert int(s_n["msgs_rr"]) == int(s_n["msgs_basic"])
    assert int(s_d["msgs_rr"]) <= int(s_n["msgs_rr"])
    np.testing.assert_array_equal(np.asarray(s_n["per_worker_rr"]),
                                  np.asarray(s_n["per_worker_basic"]))


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000))
def test_flat_matches_padded_values_and_stats(seed):
    """rr_gather_flat (csr layout) reproduces the padded channel's
    gathered values and every statistic on the same request set."""
    vals, targets, mask, M, n_loc, R = _case(seed)
    out_p, s_p = rr_gather(vals, targets, mask, M, n_loc)
    worker = jnp.broadcast_to(jnp.arange(M)[:, None], (M, R)).reshape(-1)
    out_f, s_f = rr_gather_flat(vals, targets.reshape(-1), worker,
                                mask.reshape(-1), M, n_loc)
    m = np.asarray(mask).reshape(-1)
    np.testing.assert_array_equal(np.asarray(out_p).reshape(-1)[m],
                                  np.asarray(out_f)[m])
    for k in s_p:
        np.testing.assert_array_equal(np.asarray(s_p[k]),
                                      np.asarray(s_f[k]), err_msg=k)


def test_rr_under_jit():
    """Both variants trace cleanly under jit (static M/n_loc)."""
    vals, targets, mask, M, n_loc, R = _case(7)
    f = jax.jit(lambda v, t, m: rr_gather(v, t, m, M, n_loc))
    out, stats = f(vals, targets, mask)
    assert out.shape == (M, R) and int(stats["msgs_rr"]) >= 0
    worker = jnp.broadcast_to(jnp.arange(M)[:, None], (M, R)).reshape(-1)
    g = jax.jit(lambda v, t, w, m: rr_gather_flat(v, t, w, m, M, n_loc))
    out_f, stats_f = g(vals, targets.reshape(-1), worker, mask.reshape(-1))
    assert out_f.shape == (M * R,)
    assert int(stats_f["msgs_rr"]) == int(stats["msgs_rr"])

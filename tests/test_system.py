"""End-to-end system behaviour tests."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest


def test_graph_driver_end_to_end(capsys):
    sys.argv = ["graph_run", "--algo", "hashmin", "--graph", "powerlaw",
                "--n", "2000", "--workers", "8", "--tau", "auto"]
    from repro.launch.graph_run import main
    main()
    out = capsys.readouterr().out
    assert "supersteps" in out and "msgs_total" in out


def test_serve_driver_end_to_end():
    from repro.launch.serve import run
    toks = run("tinyllama_1_1b", True, batch=2, prompt_len=8, gen=4)
    assert toks.shape == (2, 4)


def test_train_driver_loss_decreases(tmp_path):
    from repro.launch.train import run
    losses = run("tinyllama_1_1b", True, steps=30, batch=4, seq=32,
                 ckpt_dir=str(tmp_path), ckpt_every=0, lr=3e-3,
                 log_every=100)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_graph_engine_lowers_on_mesh():
    """The BSP superstep compiles SPMD over a worker mesh: the worker-axis
    transposes become all-to-alls (the multi-pod-readiness proof at test
    scale; launch/dryrun.py is the 512-device version)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.graph import generators as gen
        from repro.graph.structs import partition
        from repro.core.channels import broadcast
        g = gen.powerlaw(4000, avg_deg=6, seed=0).symmetrized()
        pg = partition(g, 8, tau=32, seed=0)
        mesh = jax.make_mesh((8,), ("w",))
        sh = NamedSharding(mesh, P("w"))
        def superstep(vals, active):
            return broadcast(pg, vals, active, op="min", use_mirroring=True)
        vals = jax.device_put(jnp.where(pg.vmask, 1.0, jnp.inf), sh)
        act = jax.device_put(pg.vmask, sh)
        lowered = jax.jit(superstep, in_shardings=(sh, sh)).lower(vals, act)
        compiled = lowered.compile()
        txt = compiled.as_text()
        has_coll = any(k in txt for k in
                       ("all-to-all", "all-reduce", "all-gather",
                        "collective-permute"))
        assert has_coll, "expected collectives in SPMD graph engine"
        inbox, stats = jax.jit(superstep, in_shardings=(sh, sh))(vals, act)
        assert bool(jnp.isfinite(stats["msgs_total"] * 1.0))
        print("OK collectives present")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_bsp_run_halts_and_accumulates():
    from repro.core import bsp

    def step(state, i):
        state = state + 1.0
        stats = {"x": jnp.ones(()), "v": jnp.ones((3,))}
        return state, state >= 5.0, stats

    final, stats, n, hist = bsp.run(step, jnp.zeros(()), 100)
    assert hist is None
    assert float(final) == 5.0 and int(n) == 5
    assert float(stats["x"]) == 5.0
    np.testing.assert_array_equal(np.asarray(stats["v"]), 5 * np.ones(3))


def test_bsp_history():
    from repro.core import bsp

    def step(state, i):
        return state + 1.0, state >= 2.0, {"m": state}

    final, stats, n, hist = bsp.run(step, jnp.zeros(()), 10,
                                    record_history=True)
    assert int(n) == 3
    np.testing.assert_allclose(np.asarray(hist["m"])[:3], [0.0, 1.0, 2.0])

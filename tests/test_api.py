"""The Engine front door: EngineConfig/RunResult round-trips and the
deprecated positional-tuple wrappers staying value-identical to the
canonical ``run()`` entry points."""
import numpy as np
import pytest

from repro.api import ALGORITHMS, Engine, EngineConfig, RunResult, config_of
from repro.graph import generators as gen
from repro.graph.structs import partition


@pytest.fixture(scope="module")
def corpus():
    g = gen.powerlaw(160, avg_deg=5, seed=1, weighted=True).symmetrized()
    return g, partition(g, 4, tau=8, seed=0, layout="csr")


def test_engine_runs_every_algorithm(corpus):
    g, pg = corpus
    eng = Engine(config_of(pg))
    params = {"sssp": dict(source=int(pg.perm[0])),
              "pagerank": dict(n_iters=4, tol=0.0),
              "gcn": dict(epochs=1, feat_dim=4, hidden=4, n_classes=2)}
    import jax.numpy as jnp
    attr = jnp.arange(pg.n_pad, dtype=jnp.float32).reshape(pg.M, pg.n_loc)
    params["attr_bcast"] = dict(attr=attr)
    for algo in ALGORITHMS:
        if algo == "gcn":
            continue  # needs a normalized graph; covered in test_gcn.py
        res = eng.run(algo, pg, **params.get(algo, {}))
        assert isinstance(res, RunResult)
        assert isinstance(res.stats, dict)
        assert res.n_supersteps >= 1


def test_engine_partitions_graph_on_the_fly(corpus):
    g, pg = corpus
    eng = Engine(layout="csr")
    res = eng.run("hashmin", g, M=4, tau=8)
    ref = eng.run("hashmin", pg)
    assert np.array_equal(np.asarray(res.state), np.asarray(ref.state))
    with pytest.raises(ValueError):
        eng.run("hashmin", g)          # Graph without M
    with pytest.raises(ValueError):
        eng.run("nope", pg)


def test_config_of_mirrors_partition(corpus):
    _, pg = corpus
    cfg = config_of(pg, backend="pallas")
    assert cfg.layout == pg.layout and cfg.balance == pg.balance
    assert cfg.split_factor == pg.split_factor and cfg.hosts == pg.hosts
    assert cfg.backend == "pallas"
    # frozen: engines can only derive new configs, never mutate
    with pytest.raises(Exception):
        cfg.backend = "dense"


def test_engine_overrides_compose():
    eng = Engine(EngineConfig(backend="pallas"), pipeline=True)
    assert eng.config.backend == "pallas" and eng.config.pipeline


def test_legacy_wrappers_match_run_results(corpus):
    """The deprecated tuple entry points are thin views of run()."""
    _, pg = corpus
    from repro.algorithms import hashmin as hm, pagerank as prm, sssp as ss
    eng = Engine(config_of(pg))

    labels, stats, n = hm.hashmin(pg)
    res = eng.run("hashmin", pg)
    assert np.array_equal(np.asarray(labels), np.asarray(res.state))
    assert int(stats["msgs_total"]) == int(res.stats["msgs_total"])
    assert int(n) == res.n_supersteps

    pr, _, n_pr = prm.pagerank(pg, n_iters=4, tol=0.0)
    res = eng.run("pagerank", pg, n_iters=4, tol=0.0)
    assert np.allclose(np.asarray(pr), np.asarray(res.state))
    assert int(n_pr) == res.n_supersteps

    src = int(pg.perm[3])
    dist, _, _ = ss.sssp(pg, src)
    res = eng.run("sssp", pg, source=src)
    assert np.array_equal(np.asarray(dist), np.asarray(res.state),
                          equal_nan=True)


def test_legacy_wrappers_warn_engine_does_not(corpus):
    """Every positional-tuple entry point emits a real
    DeprecationWarning naming its Engine replacement; the Engine front
    door itself stays warning-clean."""
    import warnings
    _, pg = corpus
    from repro.algorithms import (attr_bcast as ab, hashmin as hm, msf,
                                  pagerank as prm, sssp as ss, sv)
    import jax.numpy as jnp
    attr = jnp.ones((pg.M, pg.n_loc), jnp.float32)
    calls = [
        (hm.hashmin, (pg,), {}, "hashmin()"),
        (prm.pagerank, (pg,), dict(n_iters=2, tol=0.0), "pagerank()"),
        (ss.sssp, (pg, int(pg.perm[0])), {}, "sssp()"),
        (sv.sv, (pg,), {}, "sv()"),
        (msf.msf, (pg,), {}, "msf()"),
        (ab.attribute_broadcast, (pg,), dict(attr=attr),
         "attribute_broadcast()"),
    ]
    for fn, a, kw, name in calls:
        with pytest.warns(DeprecationWarning,
                          match="deprecated.*Engine") as rec:
            fn(*a, **kw)
        assert name in str(rec[0].message)
    eng = Engine(config_of(pg))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng.run("hashmin", pg)
        eng.run("sssp", pg, source=int(pg.perm[0]))


def test_load_report_surfaces_per_worker_telemetry(corpus):
    """RunResult.load_report(): the telemetry the elastic-repartition
    trigger consumes — per-worker message totals plus the straggler
    summary."""
    _, pg = corpus
    eng = Engine(config_of(pg))
    rep = eng.run("hashmin", pg).load_report()
    assert rep is not None
    pw = np.asarray(rep["per_worker_total"], np.float64)
    assert pw.shape == (pg.M,) and pw.sum() > 0
    assert rep["max_over_mean"] >= 1.0
    assert np.isclose(rep["max_over_mean"], pw.max() / pw.mean())
    assert len(rep["top_workers"]) == min(4, pg.M)
    assert rep["top_workers"][0] == int(np.argmax(pw))

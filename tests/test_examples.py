"""Smoke-execute the repo examples: they are the first thing a reader
runs, so a drifted API (e.g. the bsp.run arity change of PR 3) must fail
CI, not the reader.  Each example runs in a subprocess at a small scale
so the suite stays tier-1 fast."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
EXAMPLES = os.path.join(ROOT, "examples")


def _run_example(name, args=()):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    return subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, name), *args],
        capture_output=True, text=True, timeout=600, env=env)


@pytest.mark.parametrize("name,args,needle", [
    ("quickstart.py", ("2000",), "agree on all component labels"),
    ("graph_analytics.py", ("2000",), "PageRank"),
])
def test_example_runs(name, args, needle):
    r = _run_example(name, args)
    assert r.returncode == 0, (
        f"{name} exited {r.returncode}\nstdout:\n{r.stdout}\n"
        f"stderr:\n{r.stderr}")
    assert needle in r.stdout, (
        f"{name} ran but its report lost the {needle!r} line:\n{r.stdout}")

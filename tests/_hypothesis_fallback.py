"""Deterministic stand-in for ``hypothesis`` when it is not installed.

CI installs the real library (see requirements-dev.txt); hermetic
environments without it still need the suite to *collect and pass*, so
``conftest.py`` registers this module as ``hypothesis`` when the import
fails.  It implements the small API surface the suite uses — ``given``,
``settings``, and the ``integers`` / ``floats`` / ``sampled_from`` /
``booleans`` strategies — by replaying each test body over a fixed number
of seeded pseudo-random draws.  No shrinking, no database, no deadlines:
just deterministic example generation so the properties are exercised.
"""
from __future__ import annotations

import hashlib
import os
import sys
import types

import numpy as np

# Cap replay count: the suite's max_examples values are tuned for real
# hypothesis; the fallback draws uniformly so fewer examples suffice.
_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "5"))


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: np.random.RandomState):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.randint(min_value, max_value + 1)))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda rng: elems[rng.randint(len(elems))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.randint(2)))


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_fallback_max_examples", 10),
                    _MAX_EXAMPLES_CAP)
            seed = int.from_bytes(
                hashlib.sha1(fn.__qualname__.encode()).digest()[:4], "big")
            rng = np.random.RandomState(seed)
            for _ in range(max(n, 1)):
                drawn = [s.example_from(rng) for s in strategies]
                drawn_kw = {k: s.example_from(rng)
                            for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._fallback_max_examples = getattr(
            fn, "_fallback_max_examples", 10)
        return wrapper
    return deco


def install():
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans"):
        setattr(st_mod, name, globals()[name])
    hyp.strategies = st_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod

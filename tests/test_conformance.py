"""Cross-layout conformance matrix — THE proof of the csr refactor.

Every algorithm runs across {layout padded/csr} x {backend dense/pallas}
x {mirroring on/off where the algorithm exposes it} on the same
partitioned graph (same seed => same permutation => same edge order).
Results must be identical to the padded/dense reference — bitwise for the
min/max-combining algorithms (hashmin, sssp, sv, msf labels), up to
summation order for pagerank — and every msgs_*/per_worker_* statistic
must match exactly: the layout is a representation choice, never a
semantic one.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.attr_bcast import attribute_broadcast
from repro.algorithms.hashmin import hashmin
from repro.algorithms.msf import msf
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.sv import sv
from repro.graph import generators as gen
from repro.graph.structs import canonical_labels, partition

N, M, TAU, SEED = 180, 4, 8, 0

LAYOUT_BACKEND = [("padded", "dense"), ("padded", "pallas"),
                  ("csr", "dense"), ("csr", "pallas")]

_graph = None
_pgs = {}
_runs = {}


def _get_pg(layout):
    global _graph
    if _graph is None:
        _graph = gen.powerlaw(N, avg_deg=5, seed=1,
                              weighted=True).symmetrized()
    if layout not in _pgs:
        _pgs[layout] = partition(_graph, M, tau=TAU, seed=SEED,
                                 layout=layout)
    return _pgs[layout]


def _run(algo, mirror, layout, backend):
    """Run one cell of the matrix (memoized).  Returns
    (exact results tuple, approx results tuple, stats dict, supersteps)."""
    key = (algo, mirror, layout, backend)
    if key in _runs:
        return _runs[key]
    pg = _get_pg(layout)
    if algo == "hashmin":
        labels, stats, n = hashmin(pg, use_mirroring=mirror, backend=backend)
        out = ((np.asarray(labels),), (), stats, int(n))
    elif algo == "pagerank":
        pr, stats, n = pagerank(pg, n_iters=8, tol=1e-12,
                                use_mirroring=mirror, backend=backend)
        out = ((), (np.asarray(pr),), stats, int(n))
    elif algo == "sssp":
        dist, stats, n = sssp(pg, int(pg.perm[0]), use_mirroring=mirror,
                              backend=backend)
        out = ((np.asarray(dist),), (), stats, int(n))
    elif algo == "sv":
        labels, stats, n = sv(pg, backend=backend)
        out = ((np.asarray(labels),), (), stats, int(n))
    elif algo == "msf":
        (labels, tw, ne), stats, n = msf(pg, backend=backend)
        out = ((np.asarray(labels), int(ne)), (float(tw),), stats, int(n))
    elif algo == "attr_bcast":
        attr = jnp.arange(pg.n_pad, dtype=jnp.float32
                          ).reshape(pg.M, pg.n_loc) * 3
        eattr, stats = attribute_broadcast(pg, attr, backend=backend)
        # canonical per-edge form: both layouts share the same edge order,
        # csr == padded rows concatenated without the padding
        if layout == "csr":
            flat = np.asarray(eattr)
        else:
            flat = np.asarray(eattr)[np.asarray(pg.all_mask)]
        out = ((flat,), (), stats, 2)
    else:
        raise ValueError(algo)
    _runs[key] = out
    return out


def _assert_stats_equal(sa, sb, ctx):
    assert set(sa) == set(sb), ctx
    for k in sa:
        np.testing.assert_array_equal(np.asarray(sa[k]), np.asarray(sb[k]),
                                      err_msg=f"{ctx}: {k}")


CASES = ([(a, m) for a in ("hashmin", "pagerank", "sssp")
          for m in (True, False)]
         + [(a, False) for a in ("sv", "msf", "attr_bcast")])


def _cell_params():
    # the padded/pallas sv+msf cells are the two slowest of the matrix;
    # their csr twins and the padded/dense reference stay in tier-1
    out = []
    for algo, mirror in CASES:
        for layout, backend in LAYOUT_BACKEND:
            p = (algo, mirror, layout, backend)
            if algo in ("sv", "msf") and (layout, backend) == ("padded",
                                                              "pallas"):
                out.append(pytest.param(*p, marks=pytest.mark.slow))
            else:
                out.append(pytest.param(*p))
    return out


@pytest.mark.parametrize("algo,mirror,layout,backend", _cell_params())
def test_conformance_matrix(algo, mirror, layout, backend):
    ref_exact, ref_approx, ref_stats, ref_n = _run(algo, mirror,
                                                   "padded", "dense")
    exact, approx, stats, n = _run(algo, mirror, layout, backend)
    ctx = f"{algo} mirror={mirror} {layout}/{backend}"
    assert n == ref_n, ctx
    for a, b in zip(exact, ref_exact):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=ctx)
    for a, b in zip(approx, ref_approx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7, err_msg=ctx)
    _assert_stats_equal(stats, ref_stats, ctx)


def _run_shard_suite(suite):
    """Run one consolidated shard_check suite in ONE subprocess (the
    in-process tests keep the repo's one-device invariant; shard_check
    sets XLA_FLAGS for 8 host CPU devices before importing jax).  The
    suite covers the parity matrix PLUS the all-to-all HLO assertion, the
    routed-memory gate (no >= n_pad all-reduce/all-gather operand at
    D=8), the masked-request-lane parity check, and the hierarchical
    (2,4)-mesh gates (two distinct all-to-all levels per compiled
    channel, no replicated buffer at either level, per-level cap
    overflow rounds bitwise)."""
    import json
    import os
    import subprocess
    import sys
    import tempfile

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    out = os.path.join(tempfile.mkdtemp(), f"shard-{suite}.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check",
         "--suite", suite, "--out", out],
        capture_output=True, text=True, timeout=3600, env=env, cwd=root)
    assert r.returncode == 0, (r.stdout[-4000:] + "\n" + r.stderr[-4000:])
    report = json.load(open(out))
    bad = {cell: errs for cell, errs in report["cells"].items() if errs}
    assert not bad, bad
    assert report["all_to_all_in_hlo"], "join did not lower to all-to-all"
    assert report["routed_memory"]["ok"], report["routed_memory"]
    assert report["masked_lanes_ok"]
    assert report["hier_levels"]["ok"], report["hier_levels"]
    assert report["hier_caps_ok"]
    return report


def test_sharded_conformance_suite():
    """Tier-1 sharded axis, consolidated in ONE subprocess: a curated
    join-family x regime slice of the matrix (every algorithm at
    one-worker-per-device, m_loc>1 collectives, split shard-crossing
    routes, padded slicing) plus the HLO / routed-memory / masked-lane /
    hierarchical-mesh checks.  The FULL 6 x 2 x 2 x 3 x {1,2,8} matrix
    runs nightly (``-m slow``); the tier-1 slice keeps every algorithm
    at D=8 AND on the hierarchical (2,4) mesh, the m_loc>1 regime
    through S-V (every join family: broadcast, gather, runtime scatter),
    and a split cell — each both sequential and pipelined (the
    double-buffered exchange must keep the identical parity contract).
    The 2-D cells match the same single-device reference as the 1-D
    cells, pinning 2-D == 1-D bitwise / integer-exact."""
    report = _run_shard_suite("tier1")
    assert len(report["cells"]) == 40
    # the pipelined rows mirror the sequential slice cell for cell
    seq = {c for c in report["cells"] if not c.endswith("/pipeline")}
    assert {f"{c}/pipeline" for c in seq} == set(report["cells"]) - seq
    # every 1-D row has its hierarchical twin in the same slice
    hier = {c for c in report["cells"] if "devices=2x4" in c}
    assert len(hier) == len(report["cells"]) // 2
    # the acceptance gates of the 2-D mesh: two all-to-all levels per
    # compiled channel program, no replicated buffer at either level
    for name, prog in report["hier_levels"]["programs"].items():
        assert prog["two_levels"], (name, prog)
        assert prog["no_replicated_buffer"], (name, prog)
        assert set(prog["all_to_all_group_sizes"]) == {2, 4}, (name, prog)


def test_sharded_conformance_hier_axis():
    """The (hosts, devices) conformance axis: all six algorithms on
    every factorization of 8 devices — (1,8) (degenerate one-host mesh,
    must keep the exact 1-D semantics), (2,4) and (4,2) (the two proper
    hierarchies, different host/column funnel shapes) — sequential and
    pipelined.  Every cell matches the sequential single-device
    reference bitwise (min/max/int results), to tolerance (pagerank),
    and integer-exact on every statistic, so all factorizations also
    agree with the 1-D D=8 cells of the tier-1 slice."""
    report = _run_shard_suite("hier")
    assert len(report["cells"]) == 36
    tags = {c.split("devices=")[1].split("/")[0] for c in report["cells"]}
    assert tags == {"1x8", "2x4", "4x2"}


@pytest.mark.slow
def test_sharded_conformance_matrix_full():
    """Nightly: the full conformance matrix — 6 algos x 2 layouts x 2
    backends x devices {1,2,8} under balance=hash plus the csr cells of
    balance edges/split at devices {1,2,8,(2,4)} and of the PR-10
    partitioner modes (edges+refine, vertex-cut) at devices {1,8,(2,4)}
    — bitwise / integer-exact vs the unsharded reference, the whole
    matrix run both sequential and through the double-buffered
    pipeline."""
    report = _run_shard_suite("full")
    # (hash: 6*2*2*3; edges/split: 6*1*2*4 each;
    #  edges+refine/vertex-cut: 6*1*1*3 each) x {seq, pipelined}
    assert len(report["cells"]) == (72 + 48 + 48 + 18 + 18) * 2


BAL_N, BAL_M = 240, 4

_bal_graph = None
_bal_pgs = {}


def _get_bal_pg(balance):
    """Hub-heavy powerlaw (alpha=1.5): the hottest vertex outweighs a
    worker's fair share, so balance="split" actually splits workers."""
    global _bal_graph
    if _bal_graph is None:
        _bal_graph = gen.powerlaw(BAL_N, avg_deg=6, seed=2, alpha=1.5,
                                  weighted=True).symmetrized()
    if balance not in _bal_pgs:
        _bal_pgs[balance] = partition(_bal_graph, BAL_M, tau=10, seed=SEED,
                                      layout="csr", balance=balance,
                                      split_factor=1.1)
    return _bal_pgs[balance]


_canon_labels = canonical_labels


def _run_balance(algo, balance, backend):
    """Returns ([exact arrays...], approx array | None, stats) — exact
    results canonicalized to original-vertex space so modes compare."""
    pg = _get_bal_pg(balance)
    if algo == "hashmin":
        labels, stats, _ = hashmin(pg, backend=backend)
        return [_canon_labels(pg, labels)], None, stats
    if algo == "pagerank":
        pr, stats, _ = pagerank(pg, n_iters=8, tol=1e-12, backend=backend)
        return [], np.asarray(pr).reshape(-1)[pg.perm], stats
    if algo == "sssp":
        # source = relabeled id of ORIGINAL vertex 0 in each mode
        dist, stats, _ = sssp(pg, int(pg.perm[0]), backend=backend)
        return [np.asarray(dist).reshape(-1)[pg.perm]], None, stats
    if algo == "sv":
        labels, stats, _ = sv(pg, backend=backend)
        return [_canon_labels(pg, labels)], None, stats
    if algo == "msf":
        (labels, tw, ne), stats, _ = msf(pg, backend=backend)
        return ([_canon_labels(pg, labels), np.asarray(int(ne))],
                np.float32(tw), stats)
    # attr_bcast: attribute keyed by ORIGINAL id; edge order canonicalized
    # by (orig src, orig dst) so modes are comparable
    attr = np.zeros(pg.n_pad, np.float32)
    attr[pg.perm] = np.arange(pg.n, dtype=np.float32) * 3
    eattr, stats = attribute_broadcast(
        pg, jnp.asarray(attr.reshape(pg.M, pg.n_loc)), backend=backend)
    orig = np.full(pg.n_pad, -1, np.int64)
    orig[pg.perm] = np.arange(pg.n)
    key = (orig[np.asarray(pg.all_src)] * pg.n
           + orig[np.asarray(pg.all_dst)])
    return [np.asarray(eattr)[np.argsort(key)]], None, stats


# sv/msf run many BSP rounds x 3 balance modes x 2 backends: the two
# slowest cells of the in-process suite move to the nightly slow run
@pytest.mark.parametrize(
    "algo", ("hashmin", "pagerank", "sssp", "attr_bcast",
             pytest.param("sv", marks=pytest.mark.slow),
             pytest.param("msf", marks=pytest.mark.slow)))
def test_balance_axis_conformance(algo):
    """The balance mode is a placement choice, never a semantic one:
    canonicalized results agree across {hash, edges, edges+refine,
    split, vertex-cut}; within a mode the two backends agree on every
    result and statistic; and a split partition keeps the exact message
    totals of its "edges" twin for the raw (basic) channel — splitting
    only re-shards combining."""
    ref = {}
    for balance in ("hash", "edges", "edges+refine", "split",
                    "vertex-cut"):
        exact_d, approx_d, stats_d = _run_balance(algo, balance, "dense")
        exact_p, approx_p, stats_p = _run_balance(algo, balance, "pallas")
        ctx = f"{algo}/{balance}"
        for a, b in zip(exact_d, exact_p):
            np.testing.assert_array_equal(a, b, err_msg=ctx)
        _assert_stats_equal(stats_d, stats_p, ctx)
        if "ref_exact" in ref:
            for a, b in zip(exact_d, ref["ref_exact"]):
                np.testing.assert_array_equal(a, b, err_msg=ctx)
        else:
            ref["ref_exact"] = exact_d
        if approx_d is not None:
            if "ref_approx" in ref:
                np.testing.assert_allclose(approx_d, ref["ref_approx"],
                                           rtol=1e-5, atol=1e-7,
                                           err_msg=ctx)
            else:
                ref["ref_approx"] = approx_d
        ref[balance] = stats_d
    # same assignment => same raw cross-worker message count: splitting
    # must not invent or lose a single basic message
    np.testing.assert_array_equal(
        np.asarray(ref["edges"]["msgs_basic"]),
        np.asarray(ref["split"]["msgs_basic"]), err_msg=algo)


def test_split_shards_partition_csr_rows():
    """Property: the physical shard offsets of a split partition exactly
    refine the per-worker csr offsets — no edge lost, duplicated, or
    reassigned — for every edge set, across graph shapes and seeds."""
    from repro.core.cost_model import choose_split

    cases = [gen.powerlaw(300, avg_deg=6, seed=s, alpha=a, weighted=True)
             for s, a in ((0, 1.5), (1, 2.0), (2, 1.7))]
    cases.append(gen.star(200))
    cases.append(gen.chain(64))
    for i, g in enumerate(cases):
        g = g.symmetrized()
        for M, tau in ((4, 10), (8, None)):
            pg = partition(g, M, tau=tau, seed=i, layout="csr",
                           balance="split", split_factor=1.1)
            assert pg.M_phys == len(pg.phys_log) >= M
            counts = np.bincount(pg.phys_log, minlength=M)
            k = choose_split(pg.edge_load(), pg.split_factor)
            np.testing.assert_array_equal(counts, k, err_msg=str(i))
            firsts = np.concatenate([[0], np.cumsum(counts)[:-1]])
            for name, off, poff in (
                    ("eg", pg.eg_off, pg.phys_eg_off),
                    ("all", pg.all_off, pg.phys_all_off),
                    ("mir", pg.mir_eoff, pg.phys_mir_off)):
                ctx = f"case{i} M={M} tau={tau} {name}"
                assert len(poff) == pg.M_phys + 1, ctx
                assert (np.diff(poff) >= 0).all(), ctx
                # worker boundaries survive refinement: shard edge counts
                # sum to the original per-worker counts exactly
                np.testing.assert_array_equal(poff[firsts], off[:-1],
                                              err_msg=ctx)
                assert poff[-1] == off[-1], ctx
                # per-edge shard ids agree with the offsets and map back
                # to the owning logical worker
                pw = np.asarray(getattr(
                    pg, "mir_pw" if name == "mir" else f"{name}_pw"))
                np.testing.assert_array_equal(
                    pw, np.repeat(np.arange(pg.M_phys), np.diff(poff)),
                    err_msg=ctx)
            # every shard's load stays at or below the hot threshold
            # whenever its worker was split
            loads = pg.edge_load(phys=True)
            target = pg.split_factor * pg.edge_load().mean()
            split_workers = np.flatnonzero(k > 1)
            for w in split_workers:
                sel = pg.phys_log == w
                assert loads[sel].max() <= int(np.ceil(target)), (i, M, w)


def test_csr_arrays_are_flat():
    """The csr layout actually is O(E): flat 1-D edge arrays + offsets."""
    pg = _get_pg("csr")
    for name in ("eg_src", "eg_dst", "eg_w", "eg_mask",
                 "all_src", "all_dst", "all_w", "all_mask",
                 "mir_esrc", "mir_edst", "mir_emask", "mir_ew"):
        assert getattr(pg, name).ndim == 1, name
    for name in ("eg_off", "all_off", "mir_eoff"):
        off = getattr(pg, name)
        assert off is not None and off.shape == (M + 1,), name
        assert (np.diff(off) >= 0).all(), name
    assert int(pg.all_off[-1]) == _graph.m

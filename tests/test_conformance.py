"""Cross-layout conformance matrix — THE proof of the csr refactor.

Every algorithm runs across {layout padded/csr} x {backend dense/pallas}
x {mirroring on/off where the algorithm exposes it} on the same
partitioned graph (same seed => same permutation => same edge order).
Results must be identical to the padded/dense reference — bitwise for the
min/max-combining algorithms (hashmin, sssp, sv, msf labels), up to
summation order for pagerank — and every msgs_*/per_worker_* statistic
must match exactly: the layout is a representation choice, never a
semantic one.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.attr_bcast import attribute_broadcast
from repro.algorithms.hashmin import hashmin
from repro.algorithms.msf import msf
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.sv import sv
from repro.graph import generators as gen
from repro.graph.structs import partition

N, M, TAU, SEED = 180, 4, 8, 0

LAYOUT_BACKEND = [("padded", "dense"), ("padded", "pallas"),
                  ("csr", "dense"), ("csr", "pallas")]

_graph = None
_pgs = {}
_runs = {}


def _get_pg(layout):
    global _graph
    if _graph is None:
        _graph = gen.powerlaw(N, avg_deg=5, seed=1,
                              weighted=True).symmetrized()
    if layout not in _pgs:
        _pgs[layout] = partition(_graph, M, tau=TAU, seed=SEED,
                                 layout=layout)
    return _pgs[layout]


def _run(algo, mirror, layout, backend):
    """Run one cell of the matrix (memoized).  Returns
    (exact results tuple, approx results tuple, stats dict, supersteps)."""
    key = (algo, mirror, layout, backend)
    if key in _runs:
        return _runs[key]
    pg = _get_pg(layout)
    if algo == "hashmin":
        labels, stats, n = hashmin(pg, use_mirroring=mirror, backend=backend)
        out = ((np.asarray(labels),), (), stats, int(n))
    elif algo == "pagerank":
        pr, stats, n = pagerank(pg, n_iters=8, tol=1e-12,
                                use_mirroring=mirror, backend=backend)
        out = ((), (np.asarray(pr),), stats, int(n))
    elif algo == "sssp":
        dist, stats, n = sssp(pg, int(pg.perm[0]), use_mirroring=mirror,
                              backend=backend)
        out = ((np.asarray(dist),), (), stats, int(n))
    elif algo == "sv":
        labels, stats, n = sv(pg, backend=backend)
        out = ((np.asarray(labels),), (), stats, int(n))
    elif algo == "msf":
        (labels, tw, ne), stats, n = msf(pg, backend=backend)
        out = ((np.asarray(labels), int(ne)), (float(tw),), stats, int(n))
    elif algo == "attr_bcast":
        attr = jnp.arange(pg.n_pad, dtype=jnp.float32
                          ).reshape(pg.M, pg.n_loc) * 3
        eattr, stats = attribute_broadcast(pg, attr, backend=backend)
        # canonical per-edge form: both layouts share the same edge order,
        # csr == padded rows concatenated without the padding
        if layout == "csr":
            flat = np.asarray(eattr)
        else:
            flat = np.asarray(eattr)[np.asarray(pg.all_mask)]
        out = ((flat,), (), stats, 2)
    else:
        raise ValueError(algo)
    _runs[key] = out
    return out


def _assert_stats_equal(sa, sb, ctx):
    assert set(sa) == set(sb), ctx
    for k in sa:
        np.testing.assert_array_equal(np.asarray(sa[k]), np.asarray(sb[k]),
                                      err_msg=f"{ctx}: {k}")


CASES = ([(a, m) for a in ("hashmin", "pagerank", "sssp")
          for m in (True, False)]
         + [(a, False) for a in ("sv", "msf", "attr_bcast")])


@pytest.mark.parametrize("layout,backend", LAYOUT_BACKEND)
@pytest.mark.parametrize("algo,mirror", CASES)
def test_conformance_matrix(algo, mirror, layout, backend):
    ref_exact, ref_approx, ref_stats, ref_n = _run(algo, mirror,
                                                   "padded", "dense")
    exact, approx, stats, n = _run(algo, mirror, layout, backend)
    ctx = f"{algo} mirror={mirror} {layout}/{backend}"
    assert n == ref_n, ctx
    for a, b in zip(exact, ref_exact):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=ctx)
    for a, b in zip(approx, ref_approx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7, err_msg=ctx)
    _assert_stats_equal(stats, ref_stats, ctx)


def test_sharded_conformance_matrix():
    """The sharded axis of the matrix: every algo x backend x layout cell
    must be bitwise identical (min/max results; pagerank to float
    tolerance) and stats-identical between devices 1 / 2 / 8 and the
    single-device batched simulation (devices=2 pins the general
    several-workers-per-device collectives, devices=8 the
    one-worker-per-device extreme), and the dense Ch_msg join must
    lower to a real all-to-all.

    The in-process suite keeps the repo's one-device invariant, so the
    whole matrix runs in ONE subprocess with 8 forced host CPU devices
    (launch/shard_check.py sets XLA_FLAGS before importing jax)."""
    import json
    import os
    import subprocess
    import sys
    import tempfile

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    out = os.path.join(tempfile.mkdtemp(), "shard-parity.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check",
         "--devices", "1", "2", "8", "--out", out],
        capture_output=True, text=True, timeout=1800, env=env, cwd=root)
    assert r.returncode == 0, (r.stdout[-4000:] + "\n" + r.stderr[-4000:])
    report = json.load(open(out))
    bad = {cell: errs for cell, errs in report["cells"].items() if errs}
    assert not bad, bad
    assert report["all_to_all_in_hlo"], "dense join did not lower to " \
                                        "all-to-all"
    # every cell of the full 6-algo matrix must have been exercised
    assert len(report["cells"]) == 6 * 2 * 2 * 3


def test_csr_arrays_are_flat():
    """The csr layout actually is O(E): flat 1-D edge arrays + offsets."""
    pg = _get_pg("csr")
    for name in ("eg_src", "eg_dst", "eg_w", "eg_mask",
                 "all_src", "all_dst", "all_w", "all_mask",
                 "mir_esrc", "mir_edst", "mir_emask", "mir_ew"):
        assert getattr(pg, name).ndim == 1, name
    for name in ("eg_off", "all_off", "mir_eoff"):
        off = getattr(pg, name)
        assert off is not None and off.shape == (M + 1,), name
        assert (np.diff(off) >= 0).all(), name
    assert int(pg.all_off[-1]) == _graph.m

import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process).  Keep compilation caches warm across tests.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property-based tests use hypothesis; hermetic environments without it
# fall back to a deterministic replay shim (CI installs the real thing).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


RUN_SLOW = bool(os.environ.get("REPRO_RUN_SLOW"))


def sweep(n_full: int) -> int:
    """Hypothesis example budget: the full sweep nightly
    (REPRO_RUN_SLOW=1), a 1/3 budget (>= 3) in tier-1 — property tests
    keep their breadth where the wall-clock budget allows it."""
    return n_full if RUN_SLOW else max(3, n_full // 3)


def pytest_collection_modifyitems(config, items):
    """Skip ``slow``-marked tests unless explicitly requested.

    CI and the tier-1 gate run the fast suite; ``pytest -m slow`` (or
    REPRO_RUN_SLOW=1) exercises the long BSP runs locally.
    """
    markexpr = config.getoption("-m", default="") or ""
    if "slow" in markexpr or os.environ.get("REPRO_RUN_SLOW"):
        return
    skip = pytest.mark.skip(reason="slow test: run with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def union_find_cc(n, src, dst):
    p = np.arange(n)

    def find(x):
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    for s, d in zip(src, dst):
        a, b = find(s), find(d)
        if a != b:
            p[max(a, b)] = min(a, b)
    return np.array([find(i) for i in range(n)])


def kruskal_msf(n, src, dst, w):
    pairs = {}
    for s, d, ww in zip(src, dst, w):
        a, b = min(s, d), max(s, d)
        pairs[(a, b)] = min(pairs.get((a, b), np.inf), ww)
    edges = sorted((ww, a, b) for (a, b), ww in pairs.items())
    p = np.arange(n)

    def find(x):
        while p[x] != x:
            p[x] = p[p[x]]
            x = p[x]
        return x

    tw, ne = 0.0, 0
    for ww, a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            p[max(ra, rb)] = min(ra, rb)
            tw += ww
            ne += 1
    return tw, ne


@pytest.fixture(scope="session")
def oracles():
    return {"cc": union_find_cc, "msf": kruskal_msf}

"""MoE dispatch: combining semantics, capacity drops, mirrored experts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core.cost_model import moe_mirror_threshold
from repro.models.moe import moe_ffn_ref, router_probs


def _weights(key, E, D, F, n_m=1):
    ks = jax.random.split(key, 7)
    s = 0.1
    return {
        "router": jax.random.normal(ks[0], (D, E)) * s,
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * s,
        "w_up": jax.random.normal(ks[2], (E, D, F)) * s,
        "w_down": jax.random.normal(ks[3], (E, F, D)) * s,
        "w_gate_m": jax.random.normal(ks[4], (n_m, D, F)) * s,
        "w_up_m": jax.random.normal(ks[5], (n_m, D, F)) * s,
        "w_down_m": jax.random.normal(ks[6], (n_m, F, D)) * s,
    }


def test_moe_ref_no_drop_equals_dense_mix():
    """With huge capacity, dispatch == explicit per-token top-k compute."""
    key = jax.random.PRNGKey(0)
    T, D, E, F, k = 24, 16, 4, 32, 2
    w = _weights(key, E, D, F)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D))
    cfg = MoEConfig(n_experts=E, top_k=k, d_ff_expert=F, capacity_factor=50.0)
    y, aux = moe_ffn_ref(x, w, cfg)
    gates, idx, _ = router_probs(x, w["router"], k)
    ref = jnp.zeros_like(x)
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            xe = x[t][None]
            g = jnp.einsum("cd,df->cf", xe, w["w_gate"][e])
            u = jnp.einsum("cd,df->cf", xe, w["w_up"][e])
            o = jnp.einsum("cf,fd->cd", jax.nn.silu(g) * u, w["w_down"][e])
            ref = ref.at[t].add(o[0] * gates[t, j])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(0)
    T, D, E, F = 64, 8, 4, 16
    w = _weights(key, E, D, F)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D))
    lo = moe_ffn_ref(x, w, MoEConfig(E, 1, F, capacity_factor=0.25))[0]
    hi = moe_ffn_ref(x, w, MoEConfig(E, 1, F, capacity_factor=50.0))[0]
    # low capacity zeroes some tokens' outputs
    lo_norm = np.linalg.norm(np.asarray(lo), axis=-1)
    hi_norm = np.linalg.norm(np.asarray(hi), axis=-1)
    assert (lo_norm < 1e-9).sum() > 0
    assert (hi_norm < 1e-9).sum() == 0


@pytest.mark.slow  # subprocess with 8 forced host devices: nightly
def test_moe_ep_matches_ref_multidevice():
    """shard_map EP dispatch == local reference (8 fake devices)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        import sys
        sys.path.insert(0, "src")
        from repro.configs.base import MoEConfig
        from repro.models.moe import moe_ffn_ref, moe_ffn_ep, MoEContext
        key = jax.random.PRNGKey(0)
        T, D, E, F = 64, 16, 8, 32
        ks = jax.random.split(key, 7)
        s = 0.1
        w = {
            "router": jax.random.normal(ks[0], (D, E)) * s,
            "w_gate": jax.random.normal(ks[1], (E, D, F)) * s,
            "w_up": jax.random.normal(ks[2], (E, D, F)) * s,
            "w_down": jax.random.normal(ks[3], (E, F, D)) * s,
            "w_gate_m": jax.random.normal(ks[4], (2, D, F)) * s,
            "w_up_m": jax.random.normal(ks[5], (2, D, F)) * s,
            "w_down_m": jax.random.normal(ks[6], (2, F, D)) * s,
        }
        # tie mirrored copies to experts 0,1 so results are comparable
        w["w_gate_m"] = w["w_gate"][:2]
        w["w_up_m"] = w["w_up"][:2]
        w["w_down_m"] = w["w_down"][:2]
        x = jax.random.normal(jax.random.PRNGKey(1), (T, D))
        cfg = MoEConfig(n_experts=E, top_k=2, d_ff_expert=F,
                        capacity_factor=50.0, n_mirrored_experts=0)
        y_ref, aux_ref = moe_ffn_ref(x, w, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = MoEContext(mesh=mesh, ep_axis="model", dp_axes=("data",))
        y_ep, aux_ep = jax.jit(lambda x: moe_ffn_ep(x, w, cfg, ctx))(x)
        err = float(jnp.abs(y_ref - y_ep).max())
        assert err < 1e-4, f"EP mismatch: {err}"
        # mirrored experts path: results must still match the reference
        cfg_m = MoEConfig(n_experts=E, top_k=2, d_ff_expert=F,
                          capacity_factor=50.0, n_mirrored_experts=2)
        y_m, _ = jax.jit(lambda x: moe_ffn_ep(x, w, cfg_m, ctx))(x)
        err_m = float(jnp.abs(y_ref - y_m).max())
        assert err_m < 1e-4, f"mirrored mismatch: {err_m}"
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_moe_mirror_threshold_monotone():
    t1 = moe_mirror_threshold(4096, 16, 1024, 4096)
    t2 = moe_mirror_threshold(4096, 16, 1024, 4096,
                              steps_between_rebalance=100)
    assert t2 < t1  # amortizing replication lowers the bar
    assert t1 > 0

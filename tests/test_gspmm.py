"""gSpMM channel joins: forward parity vs dense scatter references,
custom-VJP gradients vs jax.grad through the dense formulation, and the
GCN training step.

Sharded gradient parity ((2,4) hierarchical mesh vs the unsharded join)
runs in a subprocess with 8 forced host devices — the in-process tests
keep the conftest one-device invariant."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspmm
from repro.graph import generators as gen
from repro.graph.structs import partition

F = 5


def _pg(layout="csr", n=150, M=8, tau=8):
    g = gen.powerlaw(n, avg_deg=4, seed=2, weighted=True).symmetrized()
    return partition(g, M, tau=tau, seed=0, layout=layout)


def _dense_ref(pg, g_src, g_dst, w):
    def fn(x, weighted=True):
        xf = x.reshape(pg.n_pad, x.shape[-1])
        contrib = xf[g_src] * w[:, None] if weighted else xf[g_src]
        out = jnp.zeros_like(xf).at[g_dst].add(contrib)
        return out.reshape(x.shape)
    return fn


def _setup(layout):
    g = gen.powerlaw(150, avg_deg=4, seed=2, weighted=True).symmetrized()
    pg = partition(g, 8, tau=8, seed=0, layout=layout)
    src = jnp.asarray(pg.perm[g.src])
    dst = jnp.asarray(pg.perm[g.dst])
    w = jnp.asarray(g.weight.astype(np.float32))
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(pg.M, pg.n_loc, F).astype(np.float32))
    cot = jnp.asarray(rng.randn(pg.M, pg.n_loc, F).astype(np.float32))
    return pg, _dense_ref(pg, src, dst, w), (src, dst, w), x, cot


@pytest.mark.parametrize("layout", ["padded", "csr"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
@pytest.mark.parametrize("kind,weighted", [("copy_u_sum", False),
                                           ("u_mul_e_sum", True)])
def test_forward_vs_dense(layout, backend, kind, weighted):
    pg, dense, _, x, _ = _setup(layout)
    out = gspmm.gspmm_join(pg, kind, backend=backend)(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(dense(x, weighted)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("layout", ["padded", "csr"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
@pytest.mark.parametrize("kind,weighted", [("copy_u_sum", False),
                                           ("u_mul_e_sum", True)])
def test_custom_vjp_vs_dense_grad(layout, backend, kind, weighted):
    """The self-adjoint backward join (one more broadcast of the
    cotangent on the symmetrized edge set) must equal XLA differentiating
    through the dense scatter-add."""
    pg, dense, _, x, cot = _setup(layout)
    f = gspmm.gspmm_join(pg, kind, backend=backend)
    gj = jax.grad(lambda z: jnp.sum(f(z) * cot))(x)
    gd = jax.grad(lambda z: jnp.sum(dense(z, weighted) * cot))(x)
    np.testing.assert_allclose(np.asarray(gj), np.asarray(gd),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("layout", ["padded", "csr"])
def test_u_mul_e_max_zero_fill(layout):
    """Forward-only max kind: empty inboxes (isolated / padded rows hold
    the -inf identity) come back zero-filled, real maxima bitwise."""
    pg, _, (src, dst, w), x, _ = _setup(layout)
    out = gspmm.u_mul_e_max(pg, x)
    xf = x.reshape(pg.n_pad, F)
    ref = jnp.full((pg.n_pad, F), -jnp.inf).at[dst].max(xf[src] * w[:, None])
    ref = jnp.where(jnp.isinf(ref), 0.0, ref).reshape(pg.M, pg.n_loc, F)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gspmm_stats_accounting():
    """The join reports the same message accounting as any channel
    broadcast: combining caps messages and the totals are integers."""
    pg, _, _, x, _ = _setup("csr")
    out, stats = gspmm.gspmm_stats(pg, "u_mul_e_sum", x)
    assert out.shape == x.shape
    assert int(stats["msgs_total"]) > 0
    # one (F,) block per active lane: identical accounting to the scalar
    # broadcast of the same activity
    from repro.core import channels
    _, sstats = channels.broadcast(pg, x[:, :, 0],
                                   jnp.ones(x.shape[:2], bool), "sum",
                                   relay="mul_w")
    for k in ("msgs_total", "msgs_combined", "msgs_mirror", "msgs_basic"):
        if k in sstats:
            assert int(stats[k]) == int(sstats[k]), k


def test_unknown_kind_raises():
    pg = _pg()
    with pytest.raises(ValueError):
        gspmm.gspmm_join(pg, "u_div_e_mean")


# ---------------------------------------------------------------------------
# GCN training (unsharded in-process; sharded parity in a subprocess)
# ---------------------------------------------------------------------------

def test_gcn_trains_and_loss_decreases():
    from repro.train import gcn
    g = gen.powerlaw(300, avg_deg=6, seed=3).symmetrized()
    g = gcn.normalize_adjacency(g)
    pg = partition(g, 8, tau=8, seed=0, layout="csr")
    _, losses = gcn.train_gcn(pg, feat_dim=16, hidden=32, n_classes=4,
                              epochs=6, lr=5e-2, seed=0)
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.05, losses


def test_gcn_layout_independent():
    """Loss history is a function of the graph, not the partition layout
    (embedding init and labels are placed through pg.perm)."""
    from repro.train import gcn
    g = gen.powerlaw(200, avg_deg=5, seed=4).symmetrized()
    g = gcn.normalize_adjacency(g)
    hist = {}
    for layout in ("csr", "padded"):
        pg = partition(g, 8, tau=8, seed=0, layout=layout)
        _, hist[layout] = gcn.train_gcn(pg, feat_dim=8, hidden=16,
                                        n_classes=4, epochs=3, lr=3e-2,
                                        seed=0)
    np.testing.assert_allclose(hist["csr"], hist["padded"],
                               rtol=1e-5, atol=1e-6)


def test_sharded_grad_and_gcn_parity_subprocess():
    """devices=(2,4) hierarchical mesh + pipeline vs the unsharded join:
    gradient allclose and identical GCN loss history (the local-loss
    gradient contract — no psum inside the differentiated function; the
    collective backward join completes every device's rows)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import exec as exec_mod
        from repro.core import gspmm
        from repro.graph import generators as gen
        from repro.graph.structs import partition
        from repro.train import gcn

        g = gen.powerlaw(150, avg_deg=4, seed=2,
                         weighted=True).symmetrized()
        pg = partition(g, 8, tau=8, seed=0, layout="csr")
        rng = np.random.RandomState(9)
        x = jnp.asarray(rng.randn(pg.M, pg.n_loc, 5).astype(np.float32))
        ct = jnp.asarray(rng.randn(pg.M, pg.n_loc, 5).astype(np.float32))
        fu = gspmm.gspmm_join(pg, "u_mul_e_sum")
        gref = np.asarray(jax.grad(lambda z: jnp.sum(fu(z) * ct))(x))

        def mk(gctx):
            fj = gspmm.gspmm_join(gctx, "u_mul_e_sum")
            def fn(xx, cc):
                # LOCAL loss only — the backward join is the collective
                return jax.grad(lambda z: jnp.sum(fj(z) * cc))(xx), {}
            return fn
        for devices, pipe in ((8, False), ((2, 4), True)):
            gs, _ = exec_mod.apply_sharded(pg, mk, (x, ct),
                                           devices=devices, pipeline=pipe)
            assert np.allclose(np.asarray(gs), gref, rtol=1e-4,
                               atol=1e-4), (devices, pipe)

        gg = gcn.normalize_adjacency(
            gen.powerlaw(200, avg_deg=5, seed=4).symmetrized())
        pg2 = partition(gg, 8, tau=8, seed=0, layout="csr")
        _, l1 = gcn.train_gcn(pg2, feat_dim=8, hidden=16, n_classes=4,
                              epochs=3, lr=3e-2, seed=0, devices=1)
        _, l8 = gcn.train_gcn(pg2, feat_dim=8, hidden=16, n_classes=4,
                              epochs=3, lr=3e-2, seed=0, devices=(2, 4),
                              pipeline=True)
        assert np.allclose(l1, l8, rtol=2e-4, atol=2e-5), (l1, l8)
        print("OK sharded parity")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout

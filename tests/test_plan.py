"""Message-plan subsystem: dense vs pallas backend equivalence.

Property: for random partitioned graphs and every combine op, the plan-
driven backend produces the *same inbox* and the *same stats dict* as the
dense reference path — min/max bitwise, sum up to summation order.  Plus
wiring tests that force the real Pallas kernel (interpret mode) through
the plan path, and layout tests for the vectorized pack helpers.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import sweep
from repro.core import plan as planlib
from repro.core.channels import broadcast, push_combined, scatter_combine
from repro.graph import generators as gen
from repro.graph.structs import partition

STAT_KEYS = ("msgs_basic", "msgs_combined", "msgs_mirror", "msgs_total",
             "per_worker_basic", "per_worker_combined", "per_worker_mirror",
             "per_worker_total")


def _assert_stats_equal(sa, sb):
    assert set(sa) == set(sb)
    for k in sa:
        np.testing.assert_array_equal(np.asarray(sa[k]), np.asarray(sb[k]),
                                      err_msg=k)


def _assert_inbox_equal(a, b, op):
    a, b = np.asarray(a), np.asarray(b)
    if op == "sum":  # summation order differs between the layouts
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    else:            # min/max are order-independent: demand bitwise equality
        np.testing.assert_array_equal(a, b)


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8),
       st.sampled_from(["min", "max", "sum"]),
       st.sampled_from([None, 6, 16]))
def test_broadcast_backend_equivalence(seed, M, op, tau):
    g = gen.powerlaw(80 + seed % 400, avg_deg=6, seed=seed % 97,
                     alpha=1.8).symmetrized()
    pg = partition(g, M, tau=tau, seed=seed % 11)
    rng = np.random.RandomState(seed % 2 ** 31)
    # strictly positive values: keeps sum's identity-count comparison
    # away from exact float cancellation
    vals = jnp.asarray(rng.rand(pg.M, pg.n_loc).astype(np.float32) + 0.5)
    active = jnp.asarray(rng.rand(pg.M, pg.n_loc) > 0.2) & pg.vmask
    for mirror in (True, False):
        a, sa = broadcast(pg, vals, active, op=op, use_mirroring=mirror,
                          backend="dense")
        b, sb = broadcast(pg, vals, active, op=op, use_mirroring=mirror,
                          backend="pallas")
        _assert_inbox_equal(a, b, op)
        _assert_stats_equal(sa, sb)


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8),
       st.sampled_from(["min", "max", "sum"]))
def test_scatter_combine_backend_equivalence(seed, M, op):
    rng = np.random.RandomState(seed % 2 ** 31)
    n_loc, K = 40 + seed % 60, 30
    targets = jnp.asarray(rng.randint(0, M * n_loc, (M, K)).astype(np.int32))
    upd = jnp.asarray((rng.randint(1, 90, (M, K))).astype(np.int32))
    mask = jnp.asarray(rng.rand(M, K) > 0.3)
    base = jnp.asarray(rng.randint(0, 1000, (M, n_loc)).astype(np.int32))
    a, sa = scatter_combine(base, targets, upd, mask, op, M, n_loc,
                            backend="dense")
    b, sb = scatter_combine(base, targets, upd, mask, op, M, n_loc,
                            backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _assert_stats_equal(sa, sb)


def test_push_combined_sorted_path_without_plan():
    """backend='pallas' with no plan (runtime targets) must still match."""
    rng = np.random.RandomState(3)
    M, n_loc, K = 6, 50, 70
    targets = jnp.asarray(rng.randint(0, M * n_loc, (M, K)).astype(np.int32))
    values = jnp.asarray(rng.randn(M, K).astype(np.float32))
    mask = jnp.asarray(rng.rand(M, K) > 0.25)
    for op in ("min", "max"):
        a, sa = push_combined(targets, values, mask, op, M, n_loc,
                              backend="dense")
        b, sb = push_combined(targets, values, mask, op, M, n_loc,
                              backend="pallas")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for k in ("msgs_basic", "msgs_combined"):
            assert int(sa[k]) == int(sb[k]), k


def test_plan_path_exercises_pallas_kernel():
    """Force interpret-mode Pallas through the plan: proves the kernel is
    actually wired into the channel layer, not just the jnp twin."""
    g = gen.powerlaw(250, avg_deg=6, seed=2, alpha=1.8).symmetrized()
    pg = partition(g, 4, tau=10, seed=0)
    vals = jnp.where(pg.vmask, 1.0, 0.0)
    try:
        planlib.set_kernel_mode("pallas")
        for op in ("min", "max", "sum"):
            a, sa = broadcast(pg, vals, pg.vmask, op=op, backend="pallas")
            d, sd = broadcast(pg, vals, pg.vmask, op=op, backend="dense")
            _assert_inbox_equal(a, d, op)
            _assert_stats_equal(sa, sd)
    finally:
        planlib.set_kernel_mode("auto")


def test_build_edge_plan_layout():
    """Every kept edge appears exactly once, in the right block row."""
    rng = np.random.RandomState(0)
    M, E, n_loc, nb, eb = 3, 40, 37, 8, 4
    dst = rng.randint(0, M * n_loc, (M, E))
    mask = rng.rand(M, E) > 0.3
    plan = planlib.build_edge_plan(dst // n_loc, dst % n_loc, mask,
                                   M, n_loc, nb=nb, eb=eb)
    assert plan.n_rows == len(plan.row_seg)
    seen = plan.row_gather[plan.row_valid]
    np.testing.assert_array_equal(np.sort(seen),
                                  np.flatnonzero(mask.reshape(-1)))
    # each packed edge's (block, local) reconstructs its destination
    B = plan.B_per_w
    for r in range(plan.n_rows):
        blk = plan.seg_blk[plan.row_seg[r]]
        w_dst, b = blk // B, blk % B
        for c in np.flatnonzero(plan.row_valid[r]):
            e = plan.row_gather[r, c]
            expect = dst.reshape(-1)[e]
            got = w_dst * n_loc + b * nb + plan.row_local[r, c]
            assert got == expect, (r, c)
    # rows of one segment share a source worker and block
    assert (plan.seg_worker >= 0).all() and (plan.seg_blk < plan.n_blocks).all()


def test_empty_plan():
    plan = planlib.build_edge_plan(np.zeros((2, 4), int),
                                   np.zeros((2, 4), int),
                                   np.zeros((2, 4), bool), 2, 10)
    inbox, (msgs, per) = planlib.combine_with_plan(
        plan, jnp.zeros((8,), jnp.float32), "min")
    assert np.isinf(np.asarray(inbox)).all()
    assert int(msgs) == 0 and np.asarray(per).sum() == 0


def test_pack_edges_vectorized_layout():
    """The vectorized pack keeps the sorted-by-block contract."""
    from repro.kernels.segment_combine.ops import pack_edges, pack_values
    rng = np.random.RandomState(1)
    E, N, nb = 500, 96, 16
    dst = rng.randint(0, N, E)
    vals = rng.randn(E).astype(np.float32)
    order, idxl = pack_edges(dst, N, nb=nb, eb_align=8)
    n_blocks = -(-N // nb)
    assert idxl.shape[0] == n_blocks
    counts = np.bincount(dst // nb, minlength=n_blocks)
    np.testing.assert_array_equal((idxl >= 0).sum(1), counts)
    pv = pack_values(vals, order, idxl, "sum")
    # reconstruct the scatter and compare against a direct bincount
    out = np.zeros(N)
    for b in range(n_blocks):
        for c in np.flatnonzero(idxl[b] >= 0):
            out[b * nb + idxl[b, c]] += pv[b, c]
    ref = np.zeros(N)
    np.add.at(ref, dst, vals)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_large_pallas_run_bounded_memory():
    """A graph where the dense (M, n_pad) partial would cost ~3 GiB of
    scatter buffers runs through the plan path (ref kernel twin on CPU)."""
    from repro.algorithms.hashmin import hashmin
    g = gen.powerlaw(200_000, avg_deg=8, seed=0, alpha=1.9).symmetrized()
    pg = partition(g, 32, tau=60, seed=0)
    labels, stats, n = hashmin(pg, backend="pallas")
    assert int(stats["msgs_combined"]) <= int(stats["msgs_basic"])
    assert int(n) >= 1

"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs; decode==forward
consistency (the cache contract)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.models import model_zoo as zoo
from repro.models.transformer import ModelContext, build_stages
from repro.train.optimizer import OptConfig
from repro.train.train_step import StepConfig, init_train_state, make_train_step

CTX = ModelContext(mesh=None, remat="none", embed_method="rr", q_chunk=8)

# tier-1 smokes one cheap representative config; the remaining
# architectures run nightly (-m slow / REPRO_RUN_SLOW=1)
FAST_ARCHS = ("tinyllama_1_1b",)
ARCH_PARAMS = [a if a in FAST_ARCHS
               else pytest.param(a, marks=pytest.mark.slow)
               for a in ARCH_IDS]


def _batch(cfg, key, B=2, S=16):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.enc_dec:
        b["enc_embeds"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0), 1, jnp.float32)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = zoo.forward_logits(params, cfg, CTX, batch["tokens"],
                                     enc_embeds=batch.get("enc_embeds"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab(1))
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, CTX, StepConfig(opt=OptConfig(lr=1e-3)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     state["params"], state2["params"])
    assert max(jax.tree.leaves(d)) > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # capacity-drop semantics differ by batch: use no-drop
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = zoo.init_params(cfg, jax.random.PRNGKey(0), 1, jnp.float32)
    B, S = 2, 24  # > reduced window (16): exercises the ring buffer
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc = (jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
           if cfg.enc_dec else None)
    full, _ = zoo.forward_logits(params, cfg, CTX, toks, enc_embeds=enc)
    _, cache = zoo.prefill(params, cfg, CTX, toks[:, :-1], enc_embeds=enc,
                           max_len=S)
    lg, _ = zoo.decode_step(params, cfg, CTX, toks[:, -1:], cache)
    assert float(jnp.max(jnp.abs(full[:, -1] - lg))) < 2e-4


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_multi_token_decode_consistency(arch):
    """Decode 4 tokens sequentially == full forward at each position."""
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = zoo.init_params(cfg, jax.random.PRNGKey(0), 1, jnp.float32)
    B, S, G = 1, 20, 4
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    enc = (jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
           if cfg.enc_dec else None)
    full, _ = zoo.forward_logits(params, cfg, CTX, toks, enc_embeds=enc)
    _, cache = zoo.prefill(params, cfg, CTX, toks[:, :S - G],
                           enc_embeds=enc, max_len=S)
    for i in range(G):
        lg, cache = zoo.decode_step(params, cfg, CTX,
                                    toks[:, S - G + i:S - G + i + 1], cache)
        ref = full[:, S - G + i]
        assert float(jnp.max(jnp.abs(ref - lg))) < 3e-4, f"pos {i}"


def test_all_40_cells_well_defined():
    """Every (arch x shape) cell is either supported or a documented skip."""
    n_cells = n_skips = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            n_cells += 1
            ok, why = cfg.shape_supported(shape)
            if not ok:
                n_skips += 1
                assert why, f"{arch}/{shape.name} skip without reason"
                assert shape.name == "long_500k"
                assert not cfg.supports_long_context
    assert n_cells == 40
    assert n_skips == 7  # pure full-attention archs skip long_500k


def test_stage_structure():
    g = get_config("gemma3_4b")
    stages = build_stages(g)
    assert sum(s.n_layers for s in stages) == g.n_layers
    # 5:1 local:global pattern
    kinds = [(s.window, s.n_layers) for s in stages]
    assert kinds[0] == (1024, 5) and kinds[1] == (0, 1)
    assert get_config("mamba2_1_3b").n_ssm_heads == 64


def test_param_counts_sane():
    pc = get_config("tinyllama_1_1b").param_counts()
    assert 0.9e9 < pc["total"] < 1.4e9
    pc = get_config("llama4_scout_17b_a16e").param_counts()
    assert 95e9 < pc["total"] < 115e9
    # top-1 of 16 experts + attn + 202k-vocab embeddings (no shared expert
    # in the assigned config)
    assert 10e9 < pc["active"] < 20e9

"""Channel semantics + theorem properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import sweep
from repro.core import cost_model
from repro.core.channels import (broadcast, push_combined, rr_gather,
                                 scatter_combine)
from repro.graph import generators as gen
from repro.graph.structs import partition


def _rand_pg(n, M, tau, seed, avg_deg=6):
    g = gen.powerlaw(n, avg_deg=avg_deg, seed=seed).symmetrized()
    return g, partition(g, M, tau=tau, seed=seed)


@settings(max_examples=sweep(15), deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(40, 400))
def test_push_combined_matches_numpy(seed, M, n):
    rng = np.random.RandomState(seed % (2 ** 31))
    n_loc = -(-n // M)
    K = 30
    targets = rng.randint(0, n, (M, K)).astype(np.int32)
    values = rng.randn(M, K).astype(np.float32)
    mask = rng.rand(M, K) > 0.3
    for op, ident, red in [("sum", 0.0, np.add), ("min", np.inf, np.minimum),
                           ("max", -np.inf, np.maximum)]:
        inbox, stats = push_combined(jnp.asarray(targets),
                                     jnp.asarray(values),
                                     jnp.asarray(mask), op, M, n_loc)
        ref = np.full(M * n_loc, ident, np.float32)
        red.at(ref, targets[mask], values[mask])
        np.testing.assert_allclose(np.asarray(inbox).reshape(-1), ref,
                                   rtol=1e-6, atol=1e-6)
        # combined <= basic (Theorem: combining only reduces)
        assert int(stats["msgs_combined"]) <= int(stats["msgs_basic"])


@settings(max_examples=sweep(10), deadline=None)
@given(st.integers(0, 10_000))
def test_rr_gather_matches_take_and_thm3(seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    M, n_loc, R = 6, 50, 80
    vals = rng.randn(M, n_loc).astype(np.float32)
    targets = rng.randint(0, M * n_loc, (M, R)).astype(np.int32)
    # skew: half the targets hit one hot vertex (the S-V pattern)
    hot = rng.randint(0, M * n_loc)
    targets[:, : R // 2] = hot
    mask = rng.rand(M, R) > 0.2
    out, stats = rr_gather(jnp.asarray(vals), jnp.asarray(targets),
                           jnp.asarray(mask), M, n_loc)
    ref = vals.reshape(-1)[targets]
    np.testing.assert_allclose(np.asarray(out)[mask], ref[mask], rtol=1e-6)
    # Theorem 3: per-target messages bounded by 2*min(M, l)
    assert int(stats["msgs_rr"]) <= int(stats["msgs_basic"])
    # the hot target contributes at most 2*M to msgs_rr but l to basic
    l_hot = int((mask[:, : R // 2]).sum())
    if l_hot > 2 * M:
        assert int(stats["msgs_basic"]) - int(stats["msgs_rr"]) >= \
            (l_hot - 2 * M) // 2


def test_mirror_bound_thm1():
    """Each active mirrored vertex sends <= min(M, d(v)) messages."""
    g, pg = _rand_pg(600, 8, tau=10, seed=1)
    vals = jnp.where(pg.vmask, 1.0, 0.0)
    _, stats = broadcast(pg, vals, pg.vmask, op="sum", use_mirroring=True)
    nmir = int((np.asarray(pg.mir_ids) < pg.n_pad).sum())
    assert nmir > 0, "test graph must have mirrored vertices"
    assert int(stats["msgs_mirror"]) <= nmir * min(pg.M, int(pg.deg.max()))
    per_v = np.asarray(pg.mir_nworkers)[:nmir]
    assert (per_v <= pg.M).all()


def test_mirroring_equivalence():
    """Mirroring is transparent: same inbox values with/without."""
    g, pg = _rand_pg(500, 8, tau=12, seed=3)
    vals = jnp.asarray(
        np.random.RandomState(0).randn(pg.M, pg.n_loc).astype(np.float32))
    for op in ["sum", "min", "max"]:
        a, _ = broadcast(pg, vals, pg.vmask, op=op, use_mirroring=True)
        b, _ = broadcast(pg, vals, pg.vmask, op=op, use_mirroring=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow  # bench --smoke asserts the same Fig.12 property at scale
def test_mirroring_reduces_messages_on_skewed_graph():
    """The paper's headline effect (Fig. 12, BTC/Hash-Min)."""
    g = gen.powerlaw(3000, avg_deg=8, seed=5, alpha=1.8).symmetrized()
    M = 16
    tau = cost_model.choose_tau(g.out_degrees(), M)
    pg_mir = partition(g, M, tau=tau, seed=0)
    vals = jnp.where(pg_mir.vmask, 1.0, 0.0)
    _, s_mir = broadcast(pg_mir, vals, pg_mir.vmask, "sum",
                         use_mirroring=True)
    _, s_nom = broadcast(pg_mir, vals, pg_mir.vmask, "sum",
                         use_mirroring=False)
    total_mir = int(s_mir["msgs_total"])
    total_nom = int(s_nom["msgs_combined"])
    basic = int(s_nom["msgs_basic"])
    assert total_mir < total_nom < basic


def test_scatter_combine_matches_numpy():
    rng = np.random.RandomState(0)
    M, n_loc, K = 4, 20, 15
    vals = rng.randint(0, 100, (M, n_loc)).astype(np.int32)
    targets = rng.randint(0, M * n_loc, (M, K)).astype(np.int32)
    upd = rng.randint(0, 100, (M, K)).astype(np.int32)
    mask = rng.rand(M, K) > 0.3
    out, _ = scatter_combine(jnp.asarray(vals), jnp.asarray(targets),
                             jnp.asarray(upd), jnp.asarray(mask),
                             "min", M, n_loc)
    ref = vals.reshape(-1).copy()
    np.minimum.at(ref, targets[mask], upd[mask])
    np.testing.assert_array_equal(np.asarray(out).reshape(-1), ref)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 512), st.floats(0.5, 60.0))
def test_thm2_threshold_properties(M, deg_avg):
    tau = cost_model.mirror_threshold(M, deg_avg)
    assert tau >= M  # never mirror below M messages' worth of degree
    # threshold grows with deg_avg (denser graphs combine better)
    assert cost_model.mirror_threshold(M, deg_avg + 1) > tau


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(1, 10 ** 6))
def test_thm1_thm3_bounds(M, d):
    assert cost_model.thm1_bound(M, d) == min(M, d)
    assert cost_model.thm3_bound(M, d) == 2 * min(M, d)


@pytest.mark.slow
def test_cost_model_tau_is_near_optimal():
    """Sweeping tau on a skewed graph: the Thm-2 tau is within 20% of the
    best tested threshold's message count (paper §7.1 claim)."""
    g = gen.powerlaw(4000, avg_deg=8, seed=9, alpha=1.8).symmetrized()
    M = 16
    deg = g.out_degrees()
    taus = [1, 10, 100, 1000, cost_model.choose_tau(deg, M)]
    counts = {}
    for tau in taus:
        pg = partition(g, M, tau=tau, seed=0)
        vals = jnp.where(pg.vmask, 1.0, 0.0)
        _, s = broadcast(pg, vals, pg.vmask, "sum", use_mirroring=True)
        counts[tau] = int(s["msgs_total"])
    best = min(counts.values())
    auto = counts[cost_model.choose_tau(deg, M)]
    assert auto <= 1.2 * best, counts

from repro.kernels.flash_attention.ops import flash_attention  # noqa
from repro.kernels.flash_attention.ref import flash_attention_ref  # noqa

"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -2.0 ** 30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, Sq, d); k/v: (BKV, Sk, d)."""
    BH, Sq, d = q.shape
    BKV, Sk, _ = k.shape
    n_rep = BH // BKV
    k = jnp.repeat(k, n_rep, axis=0)
    v = jnp.repeat(v, n_rep, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * d ** -0.5
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qp >= kp
    if window > 0:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""jit'd wrapper: (B, S, H, hd) model layout <-> kernel layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "use_kernel", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, bq=512, bk=512,
                    use_kernel=True, interpret=True):
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd).  Returns (B, Sq, H, hd).

    Row b*H + h of the flattened q maps to kv row b*K + h // (H/K):
    exactly the kernel's ``b // n_rep`` BlockSpec index map, so GQA repeats
    are never materialized.
    """
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    if use_kernel:
        o = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=interpret)
    else:
        o = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)

"""Pallas TPU flash attention (prefill/train path).

Canonical online-softmax tiling: grid (batch*q_heads, n_q_blocks,
n_kv_blocks), sequential over the kv axis with the running max/denominator
and the output accumulator in VMEM scratch (TPU grids iterate the last axis
innermost, so scratch persists across the kv sweep for a fixed (bh, q)).

GQA is handled in the BlockSpec index map (kv head = q head // n_rep), so
repeated K/V are never materialized in HBM — one of the memory-term
optimizations measured in EXPERIMENTS.md §Perf.

Supports causal masking and sliding windows; VMEM per step =
Bq*d + 2*Bk*d + Bq*Bk floats (default 512x512 blocks, d<=256: ~1.5MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (Bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (Bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         bq: int = 512, bk: int = 512,
                         interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, d); k/v: (BKV, Sk, d) with BH % BKV == 0 (GQA)."""
    BH, Sq, d = q.shape
    BKV, Sk, _ = k.shape
    n_rep = BH // BKV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    grid = (BH, Sq // bq, Sk // bk)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, n_kv=Sk // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, n_rep=n_rep: (b // n_rep, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, n_rep=n_rep: (b // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)

from repro.kernels.ssd_scan.ops import ssd_scan  # noqa
from repro.kernels.ssd_scan.ref import ssd_scan_ref  # noqa

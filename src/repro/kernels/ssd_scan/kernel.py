"""Pallas TPU kernel for the Mamba-2 SSD chunk scan.

Grid (B*H, n_chunks); the running inter-chunk state (P x N) lives in VMEM
scratch and persists across the sequential chunk axis — the HBM-resident
state tensor of a naive implementation never exists.  Per chunk, the
intra-chunk 1-semiseparable term runs as three small MXU matmuls; the state
update is one more.  VMEM per step: Q*(P+2N) inputs + Q*Q decay + P*N state
(Q=128, P=64, N=128: ~270KB f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref, *,
            q: int, p: int, n: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    A = A_ref[0].astype(jnp.float32)          # scalar (per head)
    Bm = B_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)         # (Q, N)

    dtA = dt * A                               # (Q,) <= 0
    cum = jnp.cumsum(dtA)                      # (Q,)
    xdt = x * dt[:, None]

    # intra-chunk: L[i,j] = exp(cum[i]-cum[j]) for i>=j
    seg = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(row >= col, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    state = state_ref[...]                     # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S <- exp(cum[-1]) * S + sum_j decay_to_end[j] xdt_j B_j^T
    decay_end = jnp.exp(cum[-1] - cum)         # (Q,)
    contrib = jax.lax.dot_general(xdt * decay_end[:, None], Bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(cum[-1]) * state + contrib
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_bh(x, dt, A, B, C, *, chunk: int, interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B, C: (BH, S, N).
    Returns y: (BH, S, P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    grid = (BH, S // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, q=chunk, p=P, n=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)

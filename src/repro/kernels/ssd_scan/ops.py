"""jit'd wrapper for the SSD chunk-scan kernel (model layout adapter)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bh
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, use_kernel=True, interpret=True):
    """Model layout: x (b, s, h, p); dt (b, s, h); A (h,); B/C (b, s, g, n)
    with g == 1 (groups broadcast outside).  Returns y (b, s, h, p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    Af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)
    Bf = jnp.broadcast_to(B[:, :, 0:1, :], (b, s, h, n)) \
            .transpose(0, 2, 1, 3).reshape(b * h, s, n)
    Cf = jnp.broadcast_to(C[:, :, 0:1, :], (b, s, h, n)) \
            .transpose(0, 2, 1, 3).reshape(b * h, s, n)
    if use_kernel:
        y = ssd_scan_bh(xf, dtf, Af, Bf, Cf, chunk=chunk, interpret=interpret)
    else:
        y = ssd_scan_ref(xf, dtf, Af, Bf, Cf)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)

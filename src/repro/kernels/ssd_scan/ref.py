"""Naive recurrent oracle for the SSD scan (the definition, O(S) steps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x, dt, A, B, C):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B, C: (BH, S, N)."""
    BH, S, P = x.shape
    N = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (BH,P), (BH,), (BH,N), (BH,N)
        decay = jnp.exp(dtt * A)[:, None, None]
        state = decay * state + (dtt[:, None] * xt)[:, :, None] * Bt[:, None, :]
        y = jnp.einsum("bpn,bn->bp", state, Ct)
        return state, y

    s0 = jnp.zeros((BH, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)

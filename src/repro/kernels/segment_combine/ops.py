"""jit'd wrapper + host-side edge packing for the segment_combine kernel."""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.segment_combine.kernel import segment_combine_blocks
from repro.kernels.segment_combine.ref import segment_combine_blocks_ref


def _identity(op: str, dtype) -> np.ndarray:
    """Channel identity in the *value* dtype (delegates to the canonical
    ``core.plan.identity_of``): int blocks keep their integer dtype instead
    of being coerced to float32 — vertex ids >= 2^24 survive the packing."""
    from repro.core.plan import identity_of
    return np.asarray(identity_of(op, dtype))


def pack_edges(dst: np.ndarray, n_out: int, nb: int = 256,
               eb_align: int = 512):
    """Host-side, once per graph: sort edges by destination block and pad
    each block's edge list to a common multiple-of-``eb_align`` length.

    Returns (order, idx_local (n_blocks, Eb) int32 with -1 padding) where
    ``order`` permutes per-edge values into packed layout.  Fully
    vectorized (one stable argsort + a flat scatter; no per-block loop) —
    core/plan.py generalizes the same layout to the (M, ...) worker axis.
    """
    n_blocks = -(-n_out // nb)
    blk = dst // nb
    order = np.argsort(blk, kind="stable")
    counts = np.bincount(blk, minlength=n_blocks)
    eb = max(int(counts.max()), 1)
    eb = -(-eb // eb_align) * eb_align
    idx_local = np.full((n_blocks, eb), -1, np.int32)
    starts = np.zeros(n_blocks + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    sblk = blk[order]
    pos = np.arange(len(dst)) - starts[sblk]       # rank within block
    idx_local.reshape(-1)[sblk * eb + pos] = dst[order] - sblk * nb
    return order, idx_local


def pack_values(vals: np.ndarray, order: np.ndarray, idx_local: np.ndarray,
                op: str = "sum") -> np.ndarray:
    """Scatter per-edge values into the packed (n_blocks, Eb) layout
    (vectorized flat scatter aligned with ``pack_edges``).  The packed
    array keeps ``vals.dtype``; padding slots hold the op identity for
    that dtype (the kernel ignores them via idx == -1 either way)."""
    vals = np.asarray(vals)
    n_blocks, eb = idx_local.shape
    valid = idx_local.reshape(-1) >= 0
    if vals.ndim == 2:  # feature-blocked (E, F) payload
        out = np.full((n_blocks, eb, vals.shape[1]),
                      _identity(op, vals.dtype), vals.dtype)
        out.reshape(-1, vals.shape[1])[valid] = vals[order]
        return out
    out = np.full((n_blocks, eb), _identity(op, vals.dtype), vals.dtype)
    out.reshape(-1)[valid] = vals[order]
    return out


def segment_combine(packed_vals: jax.Array, packed_idx: jax.Array, op: str,
                    nb: int, n_out: int, use_kernel: bool = True,
                    interpret: bool = True) -> jax.Array:
    """Combine packed edge messages into (n_out,) destination values —
    or (n_out, F) when ``packed_vals`` carries a feature axis."""
    fn = segment_combine_blocks if use_kernel else segment_combine_blocks_ref
    out = fn(packed_vals, packed_idx, op, nb,
             **({"interpret": interpret} if use_kernel else {}))
    if out.ndim == 3:
        return out.reshape(-1, out.shape[2])[:n_out]
    return out.reshape(-1)[:n_out]


def segment_combine_rows(packed_vals: jax.Array, packed_idx: jax.Array,
                         rows: jax.Array, op: str, nb: int,
                         use_kernel: bool = True,
                         interpret: bool = True) -> jax.Array:
    """Block-subset entry point: combine only the ``rows`` subset of a
    packed layout, returning their (len(rows), nb) combined blocks.

    Rows are independent in ``segment_combine_blocks`` (each row reduces
    its own eb lanes into its own nb destination slots), so a subset's
    blocks combine bitwise-identically to their slice of the whole-array
    combine — the property the pipelined executor relies on to overlap
    one exchange chunk's ``all_to_all`` with the next chunk's local
    combine.  ``rows`` may be any (R_sub,) int index array (static or
    traced); out-of-range / repeated rows are the caller's business."""
    fn = segment_combine_blocks if use_kernel else segment_combine_blocks_ref
    return fn(packed_vals[rows], packed_idx[rows], op, nb,
              **({"interpret": interpret} if use_kernel else {}))

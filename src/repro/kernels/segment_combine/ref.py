"""Pure-jnp oracle for the segment_combine kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_combine.kernel import sentinels


def _identity(op: str, dtype):
    neg, pos = sentinels(dtype)
    return jnp.asarray({"sum": 0, "min": pos, "max": neg}[op], dtype)


def segment_combine_blocks_ref(vals, idx, op: str, nb: int):
    n_blocks, eb = vals.shape
    ident = _identity(op, vals.dtype)
    out = jnp.full((n_blocks, nb), ident, vals.dtype)
    safe = jnp.clip(idx, 0, nb - 1)
    v = jnp.where(idx >= 0, vals, ident)
    rows = jnp.arange(n_blocks)[:, None] + jnp.zeros_like(idx)
    if op == "sum":
        return out.at[rows, safe].add(v)
    if op == "min":
        return out.at[rows, safe].min(v)
    return out.at[rows, safe].max(v)

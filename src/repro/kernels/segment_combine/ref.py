"""Pure-jnp oracle for the segment_combine kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_combine.kernel import sentinels


def _identity(op: str, dtype):
    neg, pos = sentinels(dtype)
    return jnp.asarray({"sum": 0, "min": pos, "max": neg}[op], dtype)


def segment_combine_blocks_ref(vals, idx, op: str, nb: int):
    """vals: (n_blocks, Eb) or feature-blocked (n_blocks, Eb, F); the
    trailing feature axis rides the same scatter (features never mix)."""
    n_blocks, eb = idx.shape
    ident = _identity(op, vals.dtype)
    safe = jnp.clip(idx, 0, nb - 1)
    if vals.ndim == 3:
        out = jnp.full((n_blocks, nb, vals.shape[2]), ident, vals.dtype)
        v = jnp.where((idx >= 0)[:, :, None], vals, ident)
    else:
        out = jnp.full((n_blocks, nb), ident, vals.dtype)
        v = jnp.where(idx >= 0, vals, ident)
    rows = jnp.arange(n_blocks)[:, None] + jnp.zeros_like(idx)
    if op == "sum":
        return out.at[rows, safe].add(v)
    if op == "min":
        return out.at[rows, safe].min(v)
    return out.at[rows, safe].max(v)

from repro.kernels.segment_combine.ops import segment_combine, pack_edges  # noqa

"""Pallas TPU kernel for sender-side message combining (the Ch_msg hot path).

TPU adaptation of the paper's per-message hash-table combiner (DESIGN.md §2):
a CPU combiner groups messages with a hash table — serial, pointer-chasing,
hostile to the VPU/MXU.  Here messages are pre-sorted by destination block
(host-side, once per graph) and each grid step combines one edge block into
one destination block with a *dense* compare/accumulate in VMEM:

    hit[e, n]  = (idx[e] == n)               (Eb x Nb in VMEM)
    out[n]     = op_e  hit ? val[e] : identity

For op='sum' this is literally a one-hot matmul -> MXU; min/max run on the
VPU.  Block sizes default to (Eb=512, Nb=256): hit matrix = 512KB f32,
well inside the ~16MB VMEM budget, and Nb is a multiple of the 128-lane
register width.

Dtype handling: float blocks use the finite sentinels NEG/POS as min/max
identities (VMEM-friendly; the plan layer maps them back to +-inf);
integer blocks use the dtype's iinfo bounds, which double as the exact
channel identities — id-carrying algorithms (Hash-Min, S-V) combine in
int32 so vertex ids above 2^24 stay exactly representable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.0e38
POS = 3.0e38


def sentinels(dtype):
    """(min-identity, max-identity) used inside the combine blocks."""
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return info.min, info.max
    return NEG, POS


def _kernel(vals_ref, idx_ref, out_ref, *, op: str, nb: int):
    vals = vals_ref[0, :]                       # (Eb,)
    idx = idx_ref[0, :]                         # (Eb,) local dst in [0, nb)
    eb = vals.shape[0]
    neg, pos = sentinels(vals.dtype)
    cols = jax.lax.broadcasted_iota(jnp.int32, (eb, nb), 1)
    hit = idx[:, None] == cols
    if op == "sum":
        acc = (jnp.int32 if jnp.issubdtype(vals.dtype, jnp.integer)
               else jnp.float32)
        onehot = hit.astype(vals.dtype)
        out_ref[0, :] = jax.lax.dot_general(
            vals[None, :], onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=acc)[0].astype(out_ref.dtype)
    elif op == "min":
        out_ref[0, :] = jnp.min(
            jnp.where(hit, vals[:, None], jnp.asarray(pos, vals.dtype)),
            axis=0)
    else:  # max
        out_ref[0, :] = jnp.max(
            jnp.where(hit, vals[:, None], jnp.asarray(neg, vals.dtype)),
            axis=0)


def segment_combine_blocks(vals: jax.Array, idx: jax.Array, op: str,
                           nb: int, interpret: bool = True) -> jax.Array:
    """vals/idx: (n_blocks, Eb); returns (n_blocks, nb) combined blocks.
    idx entries are block-local destinations; padding idx = -1 (never hits).
    """
    n_blocks, eb = vals.shape
    return pl.pallas_call(
        functools.partial(_kernel, op=op, nb=nb),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, eb), lambda i: (i, 0)),
                  pl.BlockSpec((1, eb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, nb), vals.dtype),
        interpret=interpret,
    )(vals, idx)

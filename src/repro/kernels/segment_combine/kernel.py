"""Pallas TPU kernel for sender-side message combining (the Ch_msg hot path).

TPU adaptation of the paper's per-message hash-table combiner (DESIGN.md §2):
a CPU combiner groups messages with a hash table — serial, pointer-chasing,
hostile to the VPU/MXU.  Here messages are pre-sorted by destination block
(host-side, once per graph) and each grid step combines one edge block into
one destination block with a *dense* compare/accumulate in VMEM:

    hit[e, n]  = (idx[e] == n)               (Eb x Nb in VMEM)
    out[n]     = op_e  hit ? val[e] : identity

For op='sum' this is literally a one-hot matmul -> MXU; min/max run on the
VPU.  Block sizes default to (Eb=512, Nb=256): hit matrix = 512KB f32,
well inside the ~16MB VMEM budget, and Nb is a multiple of the 128-lane
register width.

Dtype handling: float blocks use the finite sentinels NEG/POS as min/max
identities (VMEM-friendly; the plan layer maps them back to +-inf);
integer blocks use the dtype's iinfo bounds, which double as the exact
channel identities — id-carrying algorithms (Hash-Min, S-V) combine in
int32 so vertex ids above 2^24 stay exactly representable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.0e38
POS = 3.0e38

# largest (eb, nb, fc) min/max select tile the vector kernel materializes
# in VMEM at once: 512 x 256 x 8 x 4B = 4 MB, well inside the ~16 MB core
# budget alongside the shared (eb, nb) hit matrix
_MINMAX_FCHUNK = 8
# feature-tile width of the vector grid: one MXU-friendly 128-lane register
FEAT_TILE = 128


def sentinels(dtype):
    """(min-identity, max-identity) used inside the combine blocks.

    Floats narrower than f32 (float16: max 65504) cannot represent the
    3e38 sentinels — they would overflow to inf and break the plan
    layer's sentinel -> +-inf remap — so sub-f32 floats fall back to
    their own finfo bounds (bfloat16 shares f32's exponent range and
    keeps the canonical NEG/POS).
    """
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return info.min, info.max
    info = jnp.finfo(dtype)
    if float(info.max) < POS:
        return float(info.min), float(info.max)
    return NEG, POS


def _kernel(vals_ref, idx_ref, out_ref, *, op: str, nb: int):
    vals = vals_ref[0, :]                       # (Eb,)
    idx = idx_ref[0, :]                         # (Eb,) local dst in [0, nb)
    eb = vals.shape[0]
    neg, pos = sentinels(vals.dtype)
    cols = jax.lax.broadcasted_iota(jnp.int32, (eb, nb), 1)
    hit = idx[:, None] == cols
    if op == "sum":
        acc = (jnp.int32 if jnp.issubdtype(vals.dtype, jnp.integer)
               else jnp.float32)
        onehot = hit.astype(vals.dtype)
        out_ref[0, :] = jax.lax.dot_general(
            vals[None, :], onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=acc)[0].astype(out_ref.dtype)
    elif op == "min":
        out_ref[0, :] = jnp.min(
            jnp.where(hit, vals[:, None], jnp.asarray(pos, vals.dtype)),
            axis=0)
    else:  # max
        out_ref[0, :] = jnp.max(
            jnp.where(hit, vals[:, None], jnp.asarray(neg, vals.dtype)),
            axis=0)


def _kernel_vec(vals_ref, idx_ref, out_ref, *, op: str, nb: int):
    """Feature-blocked twin of ``_kernel``: one (edge block, feature tile)
    grid step combines an (Eb, ft) value tile into an (nb, ft) output tile.
    Features are independent, so the (Eb, nb) hit matrix is shared across
    the tile; min/max walk the tile in ``_MINMAX_FCHUNK`` column chunks so
    the (Eb, nb, fc) select never outgrows VMEM."""
    vals = vals_ref[0]                          # (Eb, ft)
    idx = idx_ref[0]                            # (Eb,)
    eb, ft = vals.shape
    neg, pos = sentinels(vals.dtype)
    cols = jax.lax.broadcasted_iota(jnp.int32, (eb, nb), 1)
    hit = idx[:, None] == cols
    if op == "sum":
        acc = (jnp.int32 if jnp.issubdtype(vals.dtype, jnp.integer)
               else jnp.float32)
        onehot = hit.astype(vals.dtype)
        # out[n, f] = sum_e onehot[e, n] * vals[e, f]  (MXU contraction)
        out_ref[0] = jax.lax.dot_general(
            onehot, vals, (((0,), (0,)), ((), ())),
            preferred_element_type=acc).astype(out_ref.dtype)
        return
    fill = jnp.asarray(pos if op == "min" else neg, vals.dtype)
    red = jnp.min if op == "min" else jnp.max
    outs = []
    for f0 in range(0, ft, _MINMAX_FCHUNK):
        v = vals[:, f0:f0 + _MINMAX_FCHUNK]     # (Eb, fc)
        outs.append(red(jnp.where(hit[:, :, None], v[:, None, :], fill),
                        axis=0))
    out_ref[0] = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)


def segment_combine_blocks(vals: jax.Array, idx: jax.Array, op: str,
                           nb: int, interpret: bool = True) -> jax.Array:
    """vals: (n_blocks, Eb) or feature-blocked (n_blocks, Eb, F);
    idx: (n_blocks, Eb).  Returns (n_blocks, nb) / (n_blocks, nb, F)
    combined blocks.  idx entries are block-local destinations; padding
    idx = -1 (never hits).  Scalar input takes the original 2-D kernel
    unchanged (the F=1 bitwise-identity contract); vector input runs a
    (block, feature-tile) grid with an inner chunk loop.
    """
    if vals.ndim == 3:
        n_blocks, eb, F = vals.shape
        ft = min(F, FEAT_TILE)
        n_ft = -(-F // ft)
        Fp = n_ft * ft
        if Fp != F:  # pad the tail tile; features never mix, slice after
            vals = jnp.pad(vals, ((0, 0), (0, 0), (0, Fp - F)))
        out = pl.pallas_call(
            functools.partial(_kernel_vec, op=op, nb=nb),
            grid=(n_blocks, n_ft),
            in_specs=[pl.BlockSpec((1, eb, ft), lambda i, j: (i, 0, j)),
                      pl.BlockSpec((1, eb), lambda i, j: (i, 0))],
            out_specs=pl.BlockSpec((1, nb, ft), lambda i, j: (i, 0, j)),
            out_shape=jax.ShapeDtypeStruct((n_blocks, nb, Fp), vals.dtype),
            interpret=interpret,
        )(vals, idx)
        return out[:, :, :F] if Fp != F else out
    n_blocks, eb = vals.shape
    return pl.pallas_call(
        functools.partial(_kernel, op=op, nb=nb),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, eb), lambda i: (i, 0)),
                  pl.BlockSpec((1, eb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, nb), vals.dtype),
        interpret=interpret,
    )(vals, idx)

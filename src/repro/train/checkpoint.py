"""Sharding-aware checkpointing with atomic commit + restart support.

Layout:  <dir>/step_<n>/
            manifest.json        (step, tree structure, shapes/dtypes)
            arr_<i>.npy          (one file per leaf; per-shard files on a
                                  real multi-host cluster — single-host here,
                                  the manifest records the intended specs)
         <dir>/LATEST            (atomic pointer, written via rename)

Fault-tolerance contract: save() is atomic (temp dir + rename), restore()
reads LATEST, restore_or_init() is the restart entrypoint the train driver
uses after preemption; garbage half-written step dirs are ignored.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Atomically write a checkpoint for ``step``; prunes old steps."""
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    flat = _leaves_with_paths(tree)
    tmp = Path(tempfile.mkdtemp(dir=d, prefix=".tmp_"))
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = d / ".LATEST_tmp"
    latest_tmp.write_text(str(step))
    os.replace(latest_tmp, d / "LATEST")          # atomic pointer flip
    _prune(d, keep)
    return str(final)


def _prune(d: Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]) for p in d.glob("step_*")),
                   reverse=True)
    for s in steps[keep:]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    step = int(p.read_text().strip())
    if not (Path(ckpt_dir) / f"step_{step}" / "manifest.json").exists():
        return None  # torn write; treat as absent
    return step


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).
    Returns (tree, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat) == len(manifest["leaves"]), "structure mismatch"
    leaves = []
    for i, (leaf, meta) in enumerate(zip(flat, manifest["leaves"])):
        arr = np.load(d / f"arr_{i}.npy")
        assert list(arr.shape) == meta["shape"]
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def restore_or_init(ckpt_dir: str, init_fn: Callable[[], Any]):
    """The restart entrypoint: resume from LATEST if present, else init.
    Returns (state, start_step)."""
    step = latest_step(ckpt_dir)
    template = init_fn()
    if step is None:
        return template, 0
    tree, step = restore(ckpt_dir, template, step)
    return tree, step


def resharded(tree: Any, mesh, spec_tree):
    """Re-place a restored (host) pytree onto a (possibly different) mesh —
    the elastic-scaling path: checkpoints are topology-independent."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree)

"""Deterministic synthetic data pipeline.

Zipf-distributed token streams (the skew that makes the request-respond
embedding lookup matter), deterministic per (seed, step, shard) so a
restarted run reproduces the exact batch sequence — the data-side half of
the fault-tolerance contract.  Sharded reads: each data-parallel rank draws
only its slice (host-side; on a real cluster each host materializes only
its local batch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    seed: int = 0


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


class SyntheticLM:
    """Stateless batch oracle: batch_at(step) is pure in (cfg, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab, cfg.zipf_a)
        # alias-free sampling via cumulative inverse
        self._cum = np.cumsum(self._probs)

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b_loc = cfg.global_batch // n_shards
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step) % (2 ** 31 - 1))
        u = rng.rand(cfg.global_batch, cfg.seq_len)
        tokens = np.searchsorted(self._cum, u).astype(np.int32)
        tokens = np.clip(tokens, 0, cfg.vocab - 1)
        return {"tokens": tokens[shard * b_loc:(shard + 1) * b_loc]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def token_stats(tokens: np.ndarray) -> Dict[str, float]:
    """Dedup statistics: how much the RR embedding channel saves (paper
    metric transferred: distinct requests / total requests)."""
    flat = tokens.reshape(-1)
    uniq = len(np.unique(flat))
    return {"tokens": int(flat.size), "unique": int(uniq),
            "dedup_ratio": uniq / flat.size}

"""2-layer GCN trained end-to-end on the sharded graph executor.

Every neighbourhood aggregation is a gSpMM channel join
(:mod:`repro.core.gspmm`): the (lanes, F) feature blocks ride the same
Ch_msg sender-side combining + Ch_mir mirror fan-out the analytics
algorithms use, so the paper's message-reduction machinery is the GNN's
message-passing layer.  Forward, per layer::

    H' = act( u_mul_e_sum(A_hat, H) @ W + b )

with ``A_hat`` the symmetrically normalized adjacency
(:func:`normalize_adjacency` — D^-1/2 A D^-1/2, symmetric, so the
custom-VJP self-adjoint backward join applies).

Differentiation inside ``shard_map`` follows the executor's gradient
contract (verified by tests/test_gspmm.py):

* the loss each device differentiates is its LOCAL masked sum — never a
  ``psum``.  Differentiating through ``psum`` under ``check_rep=False``
  multiplies cotangents by the device count; and no psum is needed,
  because the join's backward pass is itself a collective that routes
  every device's cotangent contributions to the owning rows.
* the sharded embedding grad is therefore already complete per device;
* replicated dense-parameter grads (W, b) cover only the device's rows
  and are ``psum``-reduced AFTER ``jax.grad``;
* global-norm clipping needs the cross-device norm: the sharded leaf's
  squared norm is psum'd, replicated leaves' are not.

The step is built ONCE via :func:`repro.core.exec.build_apply`
(``out_rule="auto"`` + an explicit ``is_sharded`` predicate, since a
replicated weight matrix's leading dim may coincide with ``M``) and the
epoch loop re-invokes the jitted function.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api import EngineConfig, RunResult
from repro.core import gspmm
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

_REPLICATED = ("W1", "b1", "W2", "b2")


def normalize_adjacency(g):
    """Symmetric GCN normalization on a symmetrized Graph:
    w'(u,v) = w(u,v) / sqrt(d(u) d(v)) with unweighted degrees — still
    symmetric, so the segment-sum joins stay self-adjoint."""
    import numpy as np
    from repro.graph.structs import Graph
    deg = np.maximum(g.out_degrees(), 1).astype(np.float64)
    w = g.weight if g.weight is not None else np.ones(g.m, np.float32)
    wn = (w / np.sqrt(deg[g.src] * deg[g.dst])).astype(np.float32)
    return Graph(g.n, g.src, g.dst, wn)


def gcn_labels(pg, n_classes: int, seed: int = 0):
    """Synthetic per-vertex class labels, a function of the ORIGINAL
    vertex id (partition-independent).  Returns ``(labels, mask)`` shaped
    ``(M, n_loc)``; padding slots carry label 0 with mask False."""
    import numpy as np
    rng = np.random.RandomState(seed + 7)
    lab = rng.randint(0, n_classes, size=pg.n).astype(np.int32)
    full = np.zeros(pg.n_pad, np.int32)
    full[np.asarray(pg.perm)] = lab
    labels = jnp.asarray(full).reshape(pg.M, pg.n_loc)
    mask = jnp.asarray(pg.vmask).reshape(pg.M, pg.n_loc)
    return labels, mask


def init_gcn_params(pg, feat_dim: int, hidden: int, n_classes: int,
                    seed: int = 0):
    """{emb (M, n_loc, F) sharded; W1 (F, H), b1, W2 (H, C), b2
    replicated} — Glorot-ish scaling."""
    import numpy as np
    from repro.models.embedding import node_embedding_init
    rng = np.random.RandomState(seed)
    s1 = (2.0 / (feat_dim + hidden)) ** 0.5
    s2 = (2.0 / (hidden + n_classes)) ** 0.5
    return {
        "emb": node_embedding_init(pg, feat_dim, seed=seed),
        "W1": jnp.asarray(rng.randn(feat_dim, hidden).astype(np.float32)
                          * s1),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "W2": jnp.asarray(rng.randn(hidden, n_classes).astype(np.float32)
                          * s2),
        "b2": jnp.zeros((n_classes,), jnp.float32),
    }


def gcn_forward(gctx, params, backend: str = "dense",
                use_mirroring: bool = True):
    """Two joins, two dense layers.  ``gctx`` is the PartitionedGraph or
    the device-local ShardedGraph inside a ``shard_map`` body."""
    fj = gspmm.gspmm_join(gctx, "u_mul_e_sum", backend=backend,
                          use_mirroring=use_mirroring)
    h = fj(params["emb"])
    h = jax.nn.relu(h @ params["W1"] + params["b1"])
    h = fj(h)
    return h @ params["W2"] + params["b2"]


def _xent_sum(logits, labels, mask):
    """Masked softmax cross-entropy, SUM over rows (local loss — the
    mean is taken after the psum of counts)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.sum(logits * oh, axis=-1)
    nll = (lse - picked) * mask.astype(logits.dtype)
    return jnp.sum(nll)


def make_gcn_step(cfg: OptConfig, backend: str = "dense",
                  use_mirroring: bool = True):
    """``mk(gctx) -> step(params, opt, labels, mask) ->
    ((new_params, new_opt), metrics)`` — the ``build_apply`` contract."""
    # clipping is applied here with the true cross-device norm; disarm
    # adamw_update's internal (device-local) re-clip
    inner_cfg = dataclasses.replace(cfg, clip_norm=1e30)

    def mk(gctx):
        axis = getattr(gctx, "axis", None)

        def psum_(x):
            return jax.lax.psum(x, axis) if axis is not None else x

        def step(params, opt, labels, mask):
            def loss_fn(p):
                logits = gcn_forward(gctx, p, backend=backend,
                                     use_mirroring=use_mirroring)
                return _xent_sum(logits, labels, mask)

            lsum, grads = jax.value_and_grad(loss_fn)(params)
            count = psum_(jnp.sum(mask.astype(jnp.float32)))
            loss = psum_(lsum) / count
            # emb grad is complete per device (collective backward join);
            # dense-param grads only saw this device's rows
            grads = {k: (v if k == "emb" else psum_(v))
                     for k, v in grads.items()}
            grads = jax.tree.map(lambda g_: g_ / count, grads)
            # cross-device global norm: psum the sharded leaf's sumsq only
            sumsq = {k: jnp.sum(jnp.square(v)) for k, v in grads.items()}
            gn2 = psum_(sumsq["emb"]) + sum(sumsq[k] for k in _REPLICATED)
            gnorm = jnp.sqrt(gn2)
            scale = jnp.minimum(1.0, cfg.clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g_: g_ * scale, grads)
            new_params, new_opt, m = adamw_update(params, grads, opt,
                                                  inner_cfg)
            return ((new_params, new_opt),
                    {"loss": loss, "grad_norm": gnorm, "lr": m["lr"]})

        return step

    return mk


def run(pg, config: EngineConfig | None = None, *, feat_dim: int = 32,
        hidden: int = 64, n_classes: int = 8, epochs: int = 10,
        lr: float = 1e-2, seed: int = 0,
        params: Optional[dict] = None) -> RunResult:
    """GCN training under an EngineConfig: ``state`` is the trained
    params dict, ``history`` the loss trajectory, ``n_supersteps`` the
    epoch count.  ``devices=None`` in the config maps to the D=1 mesh
    (training always runs through the sharded executor)."""
    cfg = config or EngineConfig()
    params, losses = train_gcn(
        pg, feat_dim=feat_dim, hidden=hidden, n_classes=n_classes,
        epochs=epochs, lr=lr, seed=seed, backend=cfg.backend,
        devices=cfg.devices if cfg.devices is not None else 1,
        use_mirroring=cfg.use_mirroring, pipeline=cfg.pipeline,
        params=params)
    return RunResult(state=params, stats={}, n_supersteps=epochs,
                     history=losses)


def train_gcn(pg, feat_dim: int = 32, hidden: int = 64,
              n_classes: int = 8, epochs: int = 10, lr: float = 1e-2,
              seed: int = 0, backend: str = "dense", devices=1,
              use_mirroring: bool = True, pipeline: bool = False,
              params: Optional[dict] = None) -> Tuple[dict, list]:
    """Full training run: builds the sharded step once, iterates
    ``epochs`` full-graph AdamW steps, returns ``(params, loss_history)``.
    ``pg`` must be partitioned from a :func:`normalize_adjacency`'d (or
    at least symmetrized) graph."""
    from repro.core import exec as exec_mod

    if params is None:
        params = init_gcn_params(pg, feat_dim, hidden, n_classes, seed)
    opt = init_opt_state(params)
    labels, mask = gcn_labels(pg, n_classes, seed)
    cfg = OptConfig(lr=lr, weight_decay=0.0, clip_norm=1.0,
                    warmup_steps=0, total_steps=max(epochs, 1),
                    min_lr_frac=1.0)
    kinds = (exec_mod.broadcast_plan_kinds(backend, use_mirroring)
             if backend == "pallas" else ())

    def sharded_leaf(x):
        return (getattr(x, "ndim", 0) >= 2
                and x.shape[:2] == (pg.M, pg.n_loc))

    fn, arrays = exec_mod.build_apply(
        pg, make_gcn_step(cfg, backend, use_mirroring),
        (params, opt, labels, mask), devices=devices, plan_kinds=kinds,
        pipeline=pipeline, out_rule="auto", is_sharded=sharded_leaf)

    losses = []
    for _ in range(epochs):
        (params, opt), metrics = fn(arrays, (params, opt, labels, mask))
        losses.append(float(metrics["loss"]))
    return params, losses

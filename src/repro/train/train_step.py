"""Train/serve step factories used by the launchers and the dry-run.

``make_train_step`` builds the canonical fused step:
    loss -> grad (remat per layer) -> clip -> AdamW -> new state
with optional gradient accumulation over microbatches (a ``lax.scan`` whose
carry is the grad accumulator — the memory lever for big cells).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import model_zoo as zoo
from repro.models.transformer import ModelContext
from repro.train.optimizer import (OptConfig, abstract_opt_state,
                                   adamw_update, init_opt_state)


@dataclasses.dataclass(frozen=True)
class StepConfig:
    n_microbatches: int = 1
    opt: OptConfig = OptConfig()
    aux_weight: float = 0.01


def init_train_state(cfg: ArchConfig, key, model_parallel=1,
                     dtype=jnp.float32) -> Dict[str, Any]:
    params = zoo.init_params(cfg, key, model_parallel, dtype)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_train_state(cfg: ArchConfig, model_parallel=1,
                         dtype=jnp.bfloat16) -> Dict[str, Any]:
    params = zoo.abstract_params(cfg, model_parallel, dtype)
    return {"params": params, "opt": abstract_opt_state(params)}


def make_train_step(cfg: ArchConfig, ctx: ModelContext,
                    step_cfg: StepConfig = StepConfig()):
    def loss(params, batch):
        l, metrics = zoo.loss_fn(params, cfg, ctx, batch,
                                 aux_weight=step_cfg.aux_weight)
        return l, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def single(params, batch):
        (l, metrics), grads = grad_fn(params, batch)
        return l, metrics, grads

    def accumulated(params, batch):
        n = step_cfg.n_microbatches

        def split(x):
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

        def body(acc, mb):
            (l, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / n,
                               acc, grads)
            return acc, (l, metrics)

        grads, (ls, ms) = lax.scan(body, zero, micro)
        metrics = jax.tree.map(lambda x: x.mean(), ms)
        return ls.mean(), metrics, grads

    def train_step(state, batch):
        fn = single if step_cfg.n_microbatches == 1 else accumulated
        l, metrics, grads = fn(state["params"], batch)
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], step_cfg.opt)
        metrics = dict(metrics, loss=l, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, ctx: ModelContext, max_len: int = 0):
    def prefill_step(params, batch):
        return zoo.prefill(params, cfg, ctx, batch["tokens"],
                           enc_embeds=batch.get("enc_embeds"),
                           max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig, ctx: ModelContext):
    def serve_step(params, token, cache):
        return zoo.decode_step(params, cfg, ctx, token, cache)
    return serve_step

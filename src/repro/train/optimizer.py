"""AdamW with global-norm clipping and cosine LR — hand-rolled on pytrees
(no optax in this environment), mixed-precision aware: bf16 params are
updated through an fp32 master copy carried in the optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1.0) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr \
        * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _decay_mask(p: jax.Array) -> bool:
    return p.ndim >= 2  # no weight decay on norms / per-head vectors


def init_opt_state(params: Any) -> Dict[str, Any]:
    # copy=True / fresh buffers everywhere: XLA dedups identical constants
    # and a no-op astype aliases its input — donated train states must not
    # contain twice-donated buffers.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)

    def fresh_zeros(p):
        import numpy as _np
        return jnp.asarray(_np.zeros(p.shape, _np.float32))
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(fresh_zeros, params),
        "v": jax.tree.map(fresh_zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params: Any) -> Dict[str, Any]:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"master": jax.tree.map(sds, params),
            "m": jax.tree.map(sds, params),
            "v": jax.tree.map(sds, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(grads: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(params: Any, grads: Any, opt: Dict[str, Any],
                 cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if _decay_mask(p):
            update = update + cfg.weight_decay * master
        new_master = master - lr * update
        return new_master.astype(p.dtype), new_master, m2, v2

    out = jax.tree.map(upd, params, grads, opt["master"], opt["m"], opt["v"])
    leaves = jax.tree_util.tree_structure(params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree_util.tree_map(lambda t: t[1], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[3], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_opt = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}

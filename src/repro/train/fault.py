"""Fault tolerance & elasticity utilities (1000+-node posture).

What a real deployment does and how this framework covers it:

* **Checkpoint/restart** — ``checkpoint.save`` is atomic; the train driver
  checkpoints every N steps and ``restore_or_init`` resumes bit-exactly
  (the data pipeline is stateless-per-step, so batch order replays).
* **Node failure / elastic re-mesh** — checkpoints are topology-independent
  host arrays; ``checkpoint.resharded`` re-places them on a *different*
  mesh.  For the graph engine, ``repartition`` rebuilds the M-worker layout
  for a new M (vertex ownership recomputed; BSP state carried over by
  global vertex id).
* **Straggler mitigation** — BSP supersteps are synchronous; the knobs that
  bound straggler damage are (a) even edge-count partitioning (the paper's
  own load-balancing result: mirroring + RR even out the per-worker message
  histograms, see Figs. 1-2), and (b) ``overlap`` collective scheduling in
  the LM path.  ``straggler_report`` quantifies the imbalance that remains.
* **Preemption drills** — ``simulate_preemption`` kills and resumes a train
  loop mid-run in tests, asserting loss-curve continuity.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

# straggler_report lives with the rest of the balance model in
# core/cost_model.py; re-exported here for backwards compatibility.
from repro.core.cost_model import straggler_report  # noqa: F401
from repro.graph.structs import Graph, PartitionedGraph, partition


def repartition(g: Graph, state_by_vertex: np.ndarray, old_pg: PartitionedGraph,
                new_M: int, tau=None, seed: int = 0):
    """Elastic re-mesh of a BSP computation: rebuild the partition for
    ``new_M`` workers and carry per-vertex state across by global id.

    state_by_vertex: (old_M, n_loc) array in old layout.  Returns
    (new_pg, new_state (new_M, n_loc'))."""
    flat = np.asarray(state_by_vertex).reshape(-1)[:old_pg.n_pad]
    # old layout -> original vertex order
    by_orig = np.empty(old_pg.n, flat.dtype)
    by_orig[:] = flat[old_pg.perm]
    new_pg = partition(g, new_M, tau=tau, seed=seed)
    new_flat = np.zeros(new_pg.n_pad, flat.dtype)
    new_flat[new_pg.perm] = by_orig
    return new_pg, jax.numpy.asarray(
        new_flat.reshape(new_pg.M, new_pg.n_loc))


def simulate_preemption(run_steps: Callable[[int, int], list],
                        total_steps: int, kill_at: int):
    """Drive a checkpointed training fn through a mid-run kill.

    ``run_steps(start, stop) -> list of losses`` must checkpoint internally
    and resume from its checkpoint dir.  Returns (losses_with_kill,
    losses_straight) for continuity assertions."""
    first = run_steps(0, kill_at)
    resumed = run_steps(kill_at, total_steps)  # fresh call = restart
    return first + resumed

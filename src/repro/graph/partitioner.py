"""The pluggable partitioner layer behind ``structs.partition()``.

A :class:`Partitioner` turns a host graph into a vertex relabeling plus
a declarative :class:`SplitSpec`::

    assign(g, M, hosts) -> (perm, split_spec)

``perm`` is the block relabeling (``owner(v) = perm[v] // n_loc``);
``split_spec`` tells ``partition()`` what to physically split *after*
the edge arrays are built — nothing, hot-worker edge ranges
(``balance="split"``), or the state rows of mega-hub vertices
(``balance="vertex-cut"``, realized as forced mirroring so the existing
master/replica combine and the Theorem-1 lane bound do the heavy
lifting).  ``structs.partition()``/``fold_delta()``, the cost model,
the sharded executor's cap hints and the resident service all consume
partitions through this one seam; a pinned ``perm`` bypasses it (the
fold-parity contract).

Balance modes (``partitioner_for``):

* ``"hash"``         — random permutation (Pregel baseline).
* ``"edges"``        — greedy LPT edge-cost balancing
  (``cost_model.vertex_cost`` + ``greedy_assign``).
* ``"edges+refine"`` — ``"edges"`` followed by a greedy locality
  refinement pass (``cost_model.refine_assignment``): vertices migrate
  toward the worker holding most of their neighbors, strictly
  descending the ``pair_counts`` crossness objective under the same
  slot/load caps, with cross-host lanes priced higher than
  cross-device ones when ``hosts`` is set.
* ``"split"``        — ``"edges"`` plus hot-worker edge-range splitting
  (physical shards; csr only).
* ``"vertex-cut"``   — ``"edges"`` plus mega-hub state-row splitting: a
  vertex whose degree exceeds the split threshold
  (``split_factor * m / M`` — one worker's fair edge share) is force-
  mirrored whatever ``tau``, so its fan-out rows live sharded across
  the destination workers (master keeps the state row, replicas
  combine locally, Theorem-1 bounds the lanes per target per level).

Every mode applies the host-affinity regroup (PR 7) when ``hosts`` is
given, BEFORE refinement — so refinement sees (and prices) the final
host blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core import cost_model

#: every balance mode ``partition(..., balance=...)`` accepts
BALANCES = ("hash", "edges", "edges+refine", "split", "vertex-cut")


@dataclasses.dataclass(frozen=True)
class SplitSpec:
    """What ``partition()`` should split once the edge arrays exist.

    ``kind``: ``"none"`` | ``"edge_ranges"`` (hot-worker physical
    shards) | ``"vertex_cut"`` (mega-hub forced mirroring).
    ``vc_thresh``: the vertex-cut degree threshold — ``partition()``
    folds it into the effective mirroring threshold
    (``tau_eff = min(tau_eff, vc_thresh)``), which is all the mirror
    machinery needs to split the hub's state rows.
    """
    kind: str = "none"
    split_factor: float = 1.2
    vc_thresh: Optional[int] = None


@runtime_checkable
class Partitioner(Protocol):
    """The pluggable assignment stage: graph -> (perm, SplitSpec)."""
    name: str

    def assign(self, g, M: int,
               hosts: Optional[int] = None
               ) -> Tuple[np.ndarray, SplitSpec]:
        ...


def _block_perm(assign: np.ndarray, M: int, n_loc: int) -> np.ndarray:
    """Worker assignment -> block relabeling: each worker's vertices get
    consecutive new ids in its block (``owner(v) = v // n_loc`` holds;
    blocks may have trailing unused slots)."""
    n = len(assign)
    order = np.argsort(assign, kind="stable")
    counts = np.bincount(assign, minlength=M)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    perm = np.empty(n, np.int64)
    perm[order] = assign[order] * n_loc + pos
    return perm


def host_regroup(g, perm: np.ndarray, M: int, n_loc: int,
                 hosts: int) -> np.ndarray:
    """Relabel worker blocks so heavy-communicating pairs share a host
    block of M/H workers (``cost_model.affinity_groups`` over the
    worker-pair traffic of the tentative assignment).  Slot within the
    block is preserved — only worker *placement* changes."""
    if M % hosts:
        raise ValueError(f"M={M} workers must divide over hosts={hosts}")
    n_ids = M * n_loc
    s0 = perm[g.src] // n_loc
    pkey0 = np.unique(s0 * np.int64(n_ids) + perm[g.dst])
    pc0 = np.zeros((M, M), np.int64)
    np.add.at(pc0, ((pkey0 // n_ids).astype(np.int64),
                    ((pkey0 % n_ids) // n_loc).astype(np.int64)), 1)
    worker_order = cost_model.affinity_groups(
        cost_model.worker_affinity(pc0), hosts)
    rank = np.empty(M, np.int64)
    rank[worker_order] = np.arange(M)
    return rank[perm // n_loc] * n_loc + perm % n_loc


def _maybe_regroup(g, perm, M, n_loc, hosts):
    if hosts is not None and hosts > 1:
        return host_regroup(g, perm, M, n_loc, hosts)
    return perm


@dataclasses.dataclass(frozen=True)
class HashPartitioner:
    """Random relabeling — distributionally Pregel's hash partitioning."""
    seed: int = 0
    name: str = "hash"

    def assign(self, g, M, hosts=None):
        n_loc = -(-g.n // M)
        rng = np.random.RandomState(self.seed)
        perm = rng.permutation(g.n).astype(np.int64)
        return _maybe_regroup(g, perm, M, n_loc, hosts), SplitSpec()


@dataclasses.dataclass(frozen=True)
class EdgeBalancedPartitioner:
    """Greedy LPT edge-cost balancing (``balance="edges"``)."""
    tau: Optional[int] = None
    name: str = "edges"

    def _assign_workers(self, g, M, n_loc, tau_price=None):
        deg = np.bincount(g.src, minlength=g.n)
        cost = cost_model.vertex_cost(
            deg, M, self.tau if tau_price is None else tau_price)
        return cost, cost_model.greedy_assign(cost, M, n_loc)

    def assign(self, g, M, hosts=None):
        n_loc = -(-g.n // M)
        _, wk = self._assign_workers(g, M, n_loc)
        perm = _block_perm(wk, M, n_loc)
        return _maybe_regroup(g, perm, M, n_loc, hosts), SplitSpec()


@dataclasses.dataclass(frozen=True)
class RefinedPartitioner(EdgeBalancedPartitioner):
    """``"edges"`` + the greedy crossness-descent refinement pass
    (``balance="edges+refine"``).  Refinement runs AFTER the host
    regroup so cross-host lanes are priced ``cross_host_weight`` times
    a cross-device lane."""
    rounds: int = 3
    cross_host_weight: float = 4.0
    name: str = "edges+refine"

    def assign(self, g, M, hosts=None):
        n_loc = -(-g.n // M)
        cost, wk = self._assign_workers(g, M, n_loc)
        perm = _maybe_regroup(g, _block_perm(wk, M, n_loc), M, n_loc,
                              hosts)
        weight = cost_model.pair_weight(
            M, hosts=hosts, cross_host_weight=self.cross_host_weight)
        refined, _ = cost_model.refine_assignment(
            g.src, g.dst, perm // n_loc, M, n_loc, cost,
            weight=weight, rounds=self.rounds)
        return _block_perm(refined, M, n_loc), SplitSpec()


@dataclasses.dataclass(frozen=True)
class SplitPartitioner(EdgeBalancedPartitioner):
    """``"edges"`` + hot-worker edge-range splitting into physical
    shards (``balance="split"``; boundaries are placed by
    ``partition()`` once the csr offsets exist)."""
    split_factor: float = 1.2
    name: str = "split"

    def assign(self, g, M, hosts=None):
        perm, _ = super().assign(g, M, hosts)
        return perm, SplitSpec(kind="edge_ranges",
                               split_factor=self.split_factor)


@dataclasses.dataclass(frozen=True)
class VertexCutPartitioner(EdgeBalancedPartitioner):
    """``"edges"`` + mega-hub state-row splitting
    (``balance="vertex-cut"``): any vertex whose degree exceeds one
    worker's fair edge share times ``split_factor`` is force-mirrored.
    Its adjacency rows then live sharded across the destination
    workers (the mirror csr groups them by hosting worker) while the
    master keeps the state row — the existing master/replica mirror
    combine bounds the broadcast at min(M, d) lanes (Theorem 1, per
    level on the hierarchical mesh).  Unlike ``"split"`` this lowers
    the *logical* per-worker load, so it composes with the resident
    service's ShardProfile (no physical shard meta)."""
    split_factor: float = 1.2
    name: str = "vertex-cut"

    def vc_thresh(self, g, M: int) -> int:
        """Smallest degree strictly above the split threshold."""
        return int(self.split_factor * g.m / M) + 1

    def assign(self, g, M, hosts=None):
        n_loc = -(-g.n // M)
        vc_t = self.vc_thresh(g, M)
        tau_price = min(self.tau, vc_t) if self.tau is not None else vc_t
        # price the cut vertices honestly: their per-superstep message
        # bound is the Theorem-1 min(M, d), not d
        _, wk = self._assign_workers(g, M, n_loc, tau_price=tau_price)
        perm = _block_perm(wk, M, n_loc)
        return (_maybe_regroup(g, perm, M, n_loc, hosts),
                SplitSpec(kind="vertex_cut",
                          split_factor=self.split_factor,
                          vc_thresh=vc_t))


def partitioner_for(balance: str, tau: Optional[int] = None,
                    seed: int = 0,
                    split_factor: float = 1.2) -> Partitioner:
    """The registry ``structs.partition()`` resolves ``balance`` through."""
    if balance == "hash":
        return HashPartitioner(seed=seed)
    if balance == "edges":
        return EdgeBalancedPartitioner(tau=tau)
    if balance == "edges+refine":
        return RefinedPartitioner(tau=tau)
    if balance == "split":
        return SplitPartitioner(tau=tau, split_factor=split_factor)
    if balance == "vertex-cut":
        return VertexCutPartitioner(tau=tau, split_factor=split_factor)
    raise ValueError(f"unknown balance {balance!r}; use one of "
                     f"{BALANCES}")

"""Synthetic graphs matched to the paper's dataset families.

* ``powerlaw``  — Chung-Lu-style skewed-degree graph (BTC / Twitter / LJ
  analogs: a few vertices with enormous degree).
* ``grid_road`` — 2-D lattice with random diagonal shortcuts removed
  (USA-road analog: max degree <= 4-ish, huge diameter).
* ``erdos``     — uniform random (WebUK-ish high average degree control).
* ``chain``, ``star``, ``two_cliques`` — adversarial tests.

All return host-side ``Graph``s (directed; call ``.symmetrized()`` for CC
algorithms).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structs import Graph


def _dedup(n, src, dst, w=None):
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if w is not None:
        w = w[keep]
    key = src.astype(np.int64) * n + dst
    _, idx = np.unique(key, return_index=True)
    return Graph(n, src[idx].astype(np.int64), dst[idx].astype(np.int64),
                 None if w is None else w[idx].astype(np.float32))


def powerlaw(n: int, avg_deg: float = 8.0, alpha: float = 2.0,
             seed: int = 0, weighted: bool = False) -> Graph:
    """Chung-Lu: P(edge u->v) ∝ w_u; weights ~ Zipf(alpha)."""
    rng = np.random.RandomState(seed)
    wts = (1.0 / np.arange(1, n + 1) ** (1.0 / (alpha - 1.0)))
    rng.shuffle(wts)
    p = wts / wts.sum()
    m = int(n * avg_deg)
    src = rng.choice(n, size=m, p=p)
    dst = rng.randint(0, n, size=m)
    w = rng.rand(m).astype(np.float32) + 0.01 if weighted else None
    return _dedup(n, src, dst, w)


def grid_road(side: int, seed: int = 0, weighted: bool = False) -> Graph:
    """side x side lattice, 4-neighborhood; both directions stored."""
    n = side * side
    idx = np.arange(n).reshape(side, side)
    s, d = [], []
    s.append(idx[:, :-1].ravel()); d.append(idx[:, 1:].ravel())
    s.append(idx[:-1, :].ravel()); d.append(idx[1:, :].ravel())
    src = np.concatenate(s + d)
    dst = np.concatenate(d + s)
    rng = np.random.RandomState(seed)
    w = None
    if weighted:
        half = rng.rand(len(src) // 2).astype(np.float32) + 0.01
        w = np.concatenate([half, half])  # symmetric weights
    return Graph(n, src.astype(np.int64), dst.astype(np.int64), w)


def erdos(n: int, avg_deg: float = 16.0, seed: int = 0,
          weighted: bool = False) -> Graph:
    rng = np.random.RandomState(seed)
    m = int(n * avg_deg)
    src = rng.randint(0, n, size=m)
    dst = rng.randint(0, n, size=m)
    w = rng.rand(m).astype(np.float32) + 0.01 if weighted else None
    return _dedup(n, src, dst, w)


def chain(n: int) -> Graph:
    src = np.arange(n - 1)
    dst = src + 1
    return Graph(n, np.concatenate([src, dst]),
                 np.concatenate([dst, src]))


def star(n: int) -> Graph:
    hub = np.zeros(n - 1, np.int64)
    leaf = np.arange(1, n)
    return Graph(n, np.concatenate([hub, leaf]),
                 np.concatenate([leaf, hub]))


def two_cliques(k: int) -> Graph:
    """Two k-cliques joined by one edge (CC stress)."""
    a = np.arange(k)
    s1, d1 = np.meshgrid(a, a)
    keep = s1 != d1
    s1, d1 = s1[keep], d1[keep]
    src = np.concatenate([s1, s1 + k, [0], [k]])
    dst = np.concatenate([d1, d1 + k, [k], [0]])
    return Graph(2 * k, src.astype(np.int64), dst.astype(np.int64))

"""Graph containers and the worker-partitioned representation.

Design note (hardware adaptation, DESIGN.md §2): the engine executes the
paper's per-worker logic as *batched* JAX ops over a leading worker axis
``M``.  On one CPU device that axis is a plain batch dimension (exact
M-worker simulation, exact message counts); under ``jit`` with the axis
sharded over a TPU mesh the very same code lowers to all-to-all /
all-gather collectives (the multi-pod dry-run proves it).  Static shapes
come from padding each per-worker array to the max across workers — the
padding itself visualizes the skew the paper fights.

Two edge layouts are supported (``partition(..., layout=...)``):

* ``"padded"`` — the reference layout: per-worker edge rows padded to the
  hottest worker's length, ``(M, E_hot)`` arrays.  O(M * E_hot) host
  memory; one skewed worker pads every row.
* ``"csr"``    — flat edge arrays ``(E,)`` plus per-worker ``(M+1,)`` row
  offsets (``eg_off``/``all_off``/``mir_eoff``).  O(E + M + n) memory,
  no hot-worker padding; destination-blockable by ``core/plan.py``
  without any intermediate padded unpack.  In this layout ``eg_src`` /
  ``all_src`` hold *global* source slot ids (owner derivable as
  ``src // n_loc``) and ``mir_edst`` holds *global* destination ids
  (hosting worker derivable the same way).

Vertex ids are relabeled at partition time and then block-partitioned:
``owner(v) = v // n_loc`` with O(1) owner computation.  The relabeling
is the load-balancing/locality knob (``partition(..., balance=...)``),
resolved through the pluggable partitioner layer in
``graph/partitioner.py`` (``Partitioner.assign(g, M, hosts) ->
(perm, split_spec)``):

* ``"hash"``  — a random permutation: distributionally identical to
  Pregel's hash partitioning (the reference baseline).
* ``"edges"`` — greedy edge-count-balanced assignment: vertices are priced
  by ``core/cost_model.vertex_cost`` (local edges + the Theorem-1 message
  bound) and packed LPT-style onto workers, each worker's vertices taking
  consecutive ids in its block.  Fixes multi-vertex skew; a single vertex
  hotter than a whole worker's fair share still creates a straggler.
* ``"edges+refine"`` — ``"edges"`` plus a greedy locality refinement
  pass (``cost_model.refine_assignment``) that strictly descends the
  ``pair_counts`` crossness objective under the same slot/load caps —
  fewer distinct cross-worker message pairs at equal balance.
* ``"vertex-cut"`` — ``"edges"`` plus mega-hub state-row splitting:
  vertices whose degree exceeds ``split_factor * m / M`` are force-
  mirrored (``tau_eff`` is lowered to the cut threshold), so their
  fan-out rows shard across the destination workers with the
  master/replica mirror combine — the remaining single-vertex
  straggler ``"split"`` can only shard at the edge-range level.
* ``"split"`` — ``"edges"`` plus hot-worker splitting (csr layout only):
  workers whose edge load exceeds ``split_factor x`` the mean are split
  into equal-edge-count *physical shards* by moving csr row-offset
  boundaries (``phys_*_off`` refine the per-worker offsets; ``phys_log``
  maps shards back to logical workers).  Sender-side combining and the
  Theorem-3 request dedup then run per physical shard — exactly what a
  real deployment's split worker does — while cross-worker message stats
  stay reported per *logical* worker, and ``core/exec.py`` places device
  boundaries between shards so per-device edge loads balance even under
  extreme degree skew.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.core import cost_model
from repro.graph import partitioner as partitioner_mod
from repro.graph.partitioner import BALANCES  # noqa: F401 (re-export)

LAYOUTS = ("padded", "csr")


@dataclasses.dataclass
class Graph:
    """Host-side graph: COO edge list (directed; undirected graphs store both
    directions)."""
    n: int
    src: np.ndarray  # (E,) int64
    dst: np.ndarray  # (E,) int64
    weight: Optional[np.ndarray] = None  # (E,) float32

    @property
    def m(self) -> int:
        return len(self.src)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n)

    def symmetrized(self) -> "Graph":
        """Both directions, deduplicated; undirected weights canonicalized
        to the min over the two directions (so w(a,b) == w(b,a))."""
        src = np.concatenate([self.src, self.dst])
        dst = np.concatenate([self.dst, self.src])
        w = None if self.weight is None else np.concatenate([self.weight] * 2)
        key = src.astype(np.int64) * self.n + dst
        order = np.argsort(key, kind="stable")
        key_s, src_s, dst_s = key[order], src[order], dst[order]
        first = np.concatenate([[True], key_s[1:] != key_s[:-1]])
        src_u, dst_u = src_s[first], dst_s[first]
        if w is None:
            return Graph(self.n, src_u, dst_u, None)
        wmin_dir = np.minimum.reduceat(w[order], np.flatnonzero(first))
        lo = np.minimum(src_u, dst_u)
        hi = np.maximum(src_u, dst_u)
        ukey = lo.astype(np.int64) * self.n + hi
        _, inv = np.unique(ukey, return_inverse=True)
        wpair = np.full(inv.max() + 1, np.inf, np.float32)
        np.minimum.at(wpair, inv, wmin_dir.astype(np.float32))
        return Graph(self.n, src_u, dst_u, wpair[inv].astype(np.float32))


@dataclasses.dataclass
class PartitionedGraph:
    """M-worker partition with the paper's two channels precomputed.

    Low-degree (< tau) vertices' edges go through Ch_msg (COO per worker);
    high-degree vertices are *mirrored*: their value is broadcast once per
    hosting worker and fanned out locally through the mirror COO.

    ``layout="padded"``: edge arrays are (M, E_loc) rows padded to the
    hottest worker.  ``layout="csr"``: edge arrays are flat (E,) with
    per-worker (M+1,) row offsets; ``eg_src``/``all_src`` hold *global*
    source slots and ``mir_edst`` *global* destination ids (the worker of
    an edge is ``id // n_loc``), masks are all-True (no padding exists).
    """
    n: int
    M: int
    n_loc: int
    tau: int
    perm: np.ndarray          # relabel: new_id = perm[old_id]
    inv_perm: np.ndarray

    # Ch_msg edges (from non-mirrored sources):
    eg_src: jnp.ndarray       # (M, E_loc) local src slot | (E_lo,) global
    eg_dst: jnp.ndarray       # (M, E_loc) global dst id (pad: 0) | (E_lo,)
    eg_mask: jnp.ndarray      # (M, E_loc) bool | (E_lo,) all-True
    eg_w: jnp.ndarray         # (M, E_loc) float32 | (E_lo,)

    # full adjacency (mirrored + not), for algorithms that need all edges:
    all_src: jnp.ndarray      # (M, A_loc) | (E,) global
    all_dst: jnp.ndarray
    all_mask: jnp.ndarray
    all_w: jnp.ndarray

    # mirror structures:
    mir_ids: jnp.ndarray      # (n_mir,) global ids of mirrored vertices (pad n)
    mir_slot_of: jnp.ndarray  # (M, n_loc) index into mir_ids or -1
    mir_nworkers: jnp.ndarray # (n_mir,) #workers holding a mirror (Thm 1 count)
    mir_esrc: jnp.ndarray     # (M, ME_loc) index into mir_ids | (ME,)
    mir_edst: jnp.ndarray     # (M, ME_loc) local dst slot | (ME,) global dst
    mir_emask: jnp.ndarray    # (M, ME_loc) | (ME,) all-True
    mir_ew: jnp.ndarray       # (M, ME_loc) | (ME,)

    deg: jnp.ndarray          # (M, n_loc) out-degree
    vmask: jnp.ndarray        # (M, n_loc) real-vertex mask

    layout: str = "padded"
    # csr row offsets (host numpy, (M+1,) int64); None in padded layout:
    eg_off: Optional[np.ndarray] = None
    all_off: Optional[np.ndarray] = None
    mir_eoff: Optional[np.ndarray] = None

    # -- load balancing (partition(..., balance=...)) ---------------------
    balance: str = "hash"
    split_factor: float = 1.2
    # physical worker axis (balance="split"): hot workers are split into
    # equal-edge-count shards; M_phys == M and phys_log is None otherwise.
    M_phys: int = 0
    phys_log: Optional[np.ndarray] = None      # (M_phys,) logical worker
    phys_eg_off: Optional[np.ndarray] = None   # (M_phys+1,) refined offsets
    phys_all_off: Optional[np.ndarray] = None
    phys_mir_off: Optional[np.ndarray] = None
    eg_pw: Optional[jnp.ndarray] = None        # per-edge physical shard ids
    all_pw: Optional[jnp.ndarray] = None
    mir_pw: Optional[jnp.ndarray] = None

    # (M, M) distinct (source worker, destination vertex) pair counts of
    # the full adjacency: pair_counts[s, d] bounds the combined messages
    # worker s can ever route to worker d in one superstep.  The sharded
    # executor folds worker blocks into per-device-pair caps so the
    # routed all_to_all exchanges are sized from the graph, not guessed.
    pair_counts: Optional[np.ndarray] = None

    # host-topology-aware placement (partition(..., hosts=H)): workers
    # were relabeled so block [h*M/H, (h+1)*M/H) is host h's — heavy-
    # communicating pairs (incl. mirror broadcasts) land intra-host on a
    # hierarchical (H, T) device mesh.  None = host-oblivious order.
    hosts: Optional[int] = None

    # lazily-built message plans (core/plan.py), keyed (kind, nb, eb);
    # per-instance scratch, never part of equality or the pytree.
    plan_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    @property
    def n_pad(self) -> int:
        return self.M * self.n_loc

    def edge_load(self, phys: bool = False) -> np.ndarray:
        """Per-worker edge load: Ch_msg edges stored at the source worker
        plus mirror fan-out edges at the hosting worker (== the full
        adjacency count when mirroring is off).  ``phys=True`` returns the
        per-physical-shard loads of a split partition."""
        if self.layout == "csr":
            if phys and self.phys_log is not None:
                return (np.diff(self.phys_eg_off)
                        + np.diff(self.phys_mir_off))
            return np.diff(self.eg_off) + np.diff(self.mir_eoff)
        return (np.asarray(self.eg_mask).sum(axis=1)
                + np.asarray(self.mir_emask).sum(axis=1)).astype(np.int64)

    def local_ids(self) -> jnp.ndarray:
        """(M, n_loc) global id of each local slot."""
        return (jnp.arange(self.M)[:, None] * self.n_loc
                + jnp.arange(self.n_loc)[None, :])

    # -- global reductions ------------------------------------------------
    # On one device these are plain jnp reductions; the sharded executor's
    # ``ShardedGraph`` (core/exec.py) overrides them with cross-device
    # collectives so algorithm code (halt votes, aggregators) is written
    # once and runs identically under ``shard_map``.
    def gany(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.any(x)

    def gall(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.all(x)

    def gsum(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(x)

    def gmax(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.max(x)

    def edge_src_values(self, state: jnp.ndarray, src: jnp.ndarray
                        ) -> jnp.ndarray:
        """Read per-vertex ``state`` at each edge's (locally stored) source
        endpoint, for either edge layout: ``src`` is (M, E_loc) local slots
        in the padded layout, flat (E,) global slot ids in csr."""
        if self.layout == "csr":
            return state.reshape(-1)[src]
        return state[jnp.arange(state.shape[0])[:, None], src]


def _pad_rows(rows, pad_val, dtype):
    """list of 1-D arrays -> (M, maxlen) + mask."""
    m = max((len(r) for r in rows), default=0)
    m = max(m, 1)
    out = np.full((len(rows), m), pad_val, dtype=dtype)
    mask = np.zeros((len(rows), m), bool)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
        mask[i, :len(r)] = True
    return out, mask


def canonical_labels(pg: PartitionedGraph, labels) -> np.ndarray:
    """Group labels computed in *relabeled* space (e.g. Hash-Min / S-V
    component ids, which are min relabeled ids) -> per-original-vertex
    canonical representative: the min ORIGINAL id of each group.  Makes
    results comparable across balance modes, which permute differently."""
    flat = np.asarray(labels).reshape(-1)
    lab = flat[pg.perm]
    uniq, inv = np.unique(lab, return_inverse=True)
    rep = np.full(len(uniq), pg.n, np.int64)
    np.minimum.at(rep, inv, np.arange(pg.n))
    return rep[inv]


def _refine_offsets(off: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Split each worker's [off[w], off[w+1]) edge range into k[w] near
    equal parts -> (sum(k)+1,) physical offsets refining ``off``."""
    off = np.asarray(off, np.int64)
    starts = np.repeat(off[:-1], k)
    lens = np.repeat(np.diff(off), k)
    kk = np.repeat(k, k)
    jj = (np.arange(int(k.sum()), dtype=np.int64)
          - np.repeat(np.cumsum(k) - k, k))
    return np.append(starts + (lens * jj) // kk, off[-1])


def partition(g: Graph, M: int, tau: Optional[int] = None,
              seed: int = 0, layout: str = "padded",
              balance: str = "hash",
              split_factor: float = 1.2,
              hosts: Optional[int] = None,
              perm: Optional[np.ndarray] = None) -> PartitionedGraph:
    """Partition ``g`` over M workers with mirroring threshold ``tau``
    (None => mirroring disabled, i.e. tau = inf).

    ``layout="padded"`` builds (M, E_hot) per-worker rows (reference);
    ``layout="csr"`` builds flat (E,) arrays + (M+1,) row offsets —
    O(E + M + n) host memory, no hot-worker padding.  Both layouts come
    from the same single stable sort, so corresponding edge orders are
    identical (csr == padded rows concatenated without the padding).

    ``balance`` resolves through the pluggable partitioner layer
    (``graph/partitioner.py`` — ``partitioner_for(balance).assign(g, M,
    hosts) -> (perm, split_spec)``): ``"hash"`` random, ``"edges"``
    greedy edge-balanced, ``"edges+refine"`` edge-balanced plus the
    greedy crossness-descent locality pass, ``"split"`` edge-balanced
    plus physical splitting of workers whose edge load exceeds
    ``split_factor x`` the mean (csr only), ``"vertex-cut"``
    edge-balanced plus forced mirroring of vertices whose degree
    exceeds ``split_factor * m / M`` (mega-hub state rows shard across
    the destination workers via the master/replica mirror combine).

    ``hosts=H`` makes the placement host-topology-aware for the
    hierarchical (H, T) device mesh: after the balance assignment the M
    workers are regrouped (``cost_model.affinity_groups`` over the
    worker-pair traffic matrix) so heavy-communicating pairs — combined
    residue and mirror broadcasts alike; a split worker's physical
    shards stay contiguous inside its logical block — land in the same
    host block of M/H workers, i.e. on the same host once the executor
    maps worker blocks onto the mesh.  Placement only: results are
    bitwise identical to the host-oblivious partition after
    ``canonical_labels``.

    ``perm`` pins the vertex relabeling (``new_id = perm[old_id]``)
    instead of deriving it from ``seed``/``balance``/``hosts`` — used by
    the delta-fold reference path and parity tests, where the mutated
    graph must land in exactly the placement of an existing partition.
    The host-affinity regroup is skipped too: an explicit perm is final.
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; use one of {LAYOUTS}")
    if balance not in BALANCES:
        raise ValueError(f"unknown balance {balance!r}; use one of "
                         f"{BALANCES}")
    if balance == "split" and layout != "csr":
        raise ValueError('balance="split" moves csr row-offset boundaries; '
                         'use layout="csr"')
    n_loc = -(-g.n // M)
    pinned_perm = perm is not None
    tau_eff = tau if tau is not None else g.n + 1
    if pinned_perm:
        # an explicit perm is final: the partitioner layer (and the
        # host regroup) is bypassed, and ``tau`` must already be the
        # EFFECTIVE threshold (``pg.tau`` embeds the vertex-cut fold)
        perm = np.asarray(perm, np.int64)
        if perm.shape != (g.n,):
            raise ValueError(f"perm must have shape ({g.n},), got "
                             f"{perm.shape}")
    else:
        p9r = partitioner_mod.partitioner_for(
            balance, tau=tau, seed=seed, split_factor=split_factor)
        perm, spec = p9r.assign(g, M, hosts)
        if spec.vc_thresh is not None:
            tau_eff = min(tau_eff, int(spec.vc_thresh))
    n_ids = M * n_loc
    inv = np.full(n_ids, -1, np.int64)
    inv[perm] = np.arange(g.n)
    src = perm[g.src]
    dst = perm[g.dst]
    w = g.weight if g.weight is not None else np.ones(g.m, np.float32)

    owner = src // n_loc
    deg = np.bincount(src, minlength=n_ids)
    mirrored = deg >= tau_eff                      # per (new) vertex id

    # ---- Ch_msg edges: sources below threshold -------------------------
    # one stable sort by owner, then per-worker slices (vectorized: the
    # old per-worker boolean scans were O(M * E))
    lo = ~mirrored[src]
    oorder = np.argsort(owner, kind="stable")
    osrc, odst, ow_, olo = src[oorder], dst[oorder], w[oorder], lo[oorder]
    bounds = np.searchsorted(owner[oorder], np.arange(M + 1))
    if layout == "csr":
        # flat arrays in the exact per-worker order of the padded rows;
        # global source slot ids (owner == src // n_loc by construction)
        all_src = osrc.astype(np.int32)
        all_dst = odst.astype(np.int32)
        all_w = ow_.astype(np.float32)
        all_mask = np.ones(len(osrc), bool)
        all_off = bounds.astype(np.int64)
        eg_src = osrc[olo].astype(np.int32)
        eg_dst = odst[olo].astype(np.int32)
        eg_w = ow_[olo].astype(np.float32)
        eg_mask = np.ones(len(eg_src), bool)
        eg_off = np.searchsorted(owner[oorder][olo],
                                 np.arange(M + 1)).astype(np.int64)
    else:
        eg_rows_s, eg_rows_d, eg_rows_w = [], [], []
        all_rows_s, all_rows_d, all_rows_w = [], [], []
        for wk in range(M):
            sl = slice(bounds[wk], bounds[wk + 1])
            all_rows_s.append((osrc[sl] % n_loc).astype(np.int32))
            all_rows_d.append(odst[sl].astype(np.int32))
            all_rows_w.append(ow_[sl].astype(np.float32))
            keep = olo[sl]
            eg_rows_s.append((osrc[sl][keep] % n_loc).astype(np.int32))
            eg_rows_d.append(odst[sl][keep].astype(np.int32))
            eg_rows_w.append(ow_[sl][keep].astype(np.float32))
        eg_src, eg_mask = _pad_rows(eg_rows_s, 0, np.int32)
        eg_dst, _ = _pad_rows(eg_rows_d, 0, np.int32)
        eg_w, _ = _pad_rows(eg_rows_w, 0.0, np.float32)
        all_src, all_mask = _pad_rows(all_rows_s, 0, np.int32)
        all_dst, _ = _pad_rows(all_rows_d, 0, np.int32)
        all_w, _ = _pad_rows(all_rows_w, 0.0, np.float32)
        eg_off = all_off = None

    # ---- mirrors: group each high-deg vertex's edges by dst worker -----
    mir_vertex_ids = np.flatnonzero(mirrored)          # sorted global ids
    n_mir = max(len(mir_vertex_ids), 1)
    mir_slot_of = np.full((M, n_loc), -1, np.int32)
    mir_slot_of.reshape(-1)[mir_vertex_ids] = np.arange(len(mir_vertex_ids))

    hi = mirrored[src]
    hsrc, hdst, hw = src[hi], dst[hi], w[hi]
    dst_owner = hdst // n_loc
    es_all = np.zeros(0, np.int32)
    edg_all = np.zeros(0, np.int64)                    # global dst ids
    ew_all = np.zeros(0, np.float32)
    hb = np.zeros(M + 1, np.int64)
    nworkers = np.zeros(n_mir, np.int64)
    if len(hsrc):
        # vectorized grouping: sort once by (dst worker, src, dst), then
        # slice per hosting worker (was a Python loop over every edge)
        order = np.lexsort((hdst, hsrc, dst_owner))
        hsrc, hdst, hw, dst_owner = (hsrc[order], hdst[order], hw[order],
                                     dst_owner[order])
        mir_idx_of = np.full(n_ids, -1, np.int64)
        mir_idx_of[mir_vertex_ids] = np.arange(len(mir_vertex_ids))
        es_all = mir_idx_of[hsrc].astype(np.int32)
        edg_all = hdst.astype(np.int64)
        ew_all = hw.astype(np.float32)
        hb = np.searchsorted(dst_owner, np.arange(M + 1)).astype(np.int64)
        # workers per mirrored vertex
        pair = np.unique(hsrc * np.int64(M) + dst_owner)
        cnt = np.bincount((pair // M).astype(np.int64), minlength=n_ids)
        nworkers = cnt[mir_vertex_ids] if len(mir_vertex_ids) else nworkers
    if layout == "csr":
        mir_esrc = es_all
        mir_edst = edg_all.astype(np.int32)            # global dst ids
        mir_ew = ew_all
        mir_emask = np.ones(len(es_all), bool)
        mir_eoff = hb
    else:
        rows_es = [es_all[hb[ow]:hb[ow + 1]] for ow in range(M)]
        rows_ed = [(edg_all[hb[ow]:hb[ow + 1]] % n_loc).astype(np.int32)
                   for ow in range(M)]
        rows_ew = [ew_all[hb[ow]:hb[ow + 1]] for ow in range(M)]
        mir_esrc, mir_emask = _pad_rows(rows_es, 0, np.int32)
        mir_edst, _ = _pad_rows(rows_ed, 0, np.int32)
        mir_ew, _ = _pad_rows(rows_ew, 0.0, np.float32)
        mir_eoff = None

    deg_pad = deg.astype(np.int32).reshape(M, n_loc)
    vmask = np.zeros((M, n_loc), bool)
    vmask.reshape(-1)[perm] = True

    # per-destination caps (Theorem-1-style static bound): distinct
    # (source worker, destination vertex) pairs per worker pair — one
    # unique over the edge list, O(E log E) like the layout sorts above
    pkey = np.unique(owner.astype(np.int64) * n_ids + dst)
    pair_counts = np.zeros((M, M), np.int64)
    np.add.at(pair_counts,
              ((pkey // n_ids).astype(np.int64),
               ((pkey % n_ids) // n_loc).astype(np.int64)), 1)

    mir_ids_arr = np.full(n_mir, M * n_loc, np.int32)
    mir_ids_arr[:len(mir_vertex_ids)] = mir_vertex_ids

    # ---- hot-worker splitting: physical shard boundaries ---------------
    M_phys, phys_log = M, None
    phys_eg = phys_all = phys_mir = None
    eg_pw = all_pw = mir_pw = None
    if balance == "split":
        load = np.diff(eg_off) + np.diff(hb)
        k = cost_model.choose_split(load, split_factor)
        M_phys = int(k.sum())
        phys_log = np.repeat(np.arange(M, dtype=np.int64), k)
        phys_eg = _refine_offsets(eg_off, k)
        phys_all = _refine_offsets(all_off, k)
        phys_mir = _refine_offsets(hb, k)
        pids = np.arange(M_phys, dtype=np.int32)
        eg_pw = jnp.asarray(np.repeat(pids, np.diff(phys_eg)))
        all_pw = jnp.asarray(np.repeat(pids, np.diff(phys_all)))
        mir_pw_np = np.repeat(pids, np.diff(phys_mir))
        mir_pw = jnp.asarray(mir_pw_np)
        if len(hsrc):
            # Theorem-1 accounting at shard granularity: a mirrored vertex
            # is broadcast once per *physical shard* hosting its edges
            spair = np.unique(es_all.astype(np.int64) * M_phys + mir_pw_np)
            nworkers = np.bincount(spair // M_phys, minlength=n_mir)

    return PartitionedGraph(
        n=g.n, M=M, n_loc=n_loc, tau=int(tau_eff), perm=perm, inv_perm=inv,
        eg_src=jnp.asarray(eg_src), eg_dst=jnp.asarray(eg_dst),
        eg_mask=jnp.asarray(eg_mask), eg_w=jnp.asarray(eg_w),
        all_src=jnp.asarray(all_src), all_dst=jnp.asarray(all_dst),
        all_mask=jnp.asarray(all_mask), all_w=jnp.asarray(all_w),
        mir_ids=jnp.asarray(mir_ids_arr),
        mir_slot_of=jnp.asarray(mir_slot_of),
        mir_nworkers=jnp.asarray(nworkers),
        mir_esrc=jnp.asarray(mir_esrc), mir_edst=jnp.asarray(mir_edst),
        mir_emask=jnp.asarray(mir_emask), mir_ew=jnp.asarray(mir_ew),
        deg=jnp.asarray(deg_pad), vmask=jnp.asarray(vmask),
        layout=layout, eg_off=eg_off, all_off=all_off, mir_eoff=mir_eoff,
        balance=balance, split_factor=split_factor, M_phys=M_phys,
        phys_log=phys_log, phys_eg_off=phys_eg, phys_all_off=phys_all,
        phys_mir_off=phys_mir, eg_pw=eg_pw, all_pw=all_pw, mir_pw=mir_pw,
        pair_counts=pair_counts, hosts=hosts,
    )


# ---------------------------------------------------------------------------
# Streaming mutations: delta-CSR segments folded into the flat layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EdgeDelta:
    """A streaming mutation batch, in ORIGINAL vertex-id space.

    ``add_*`` are appended as-is (parallel edges allowed, like the base
    edge list); ``rem_*`` remove every stored edge matching the (src,
    dst) pair, whatever its weight.  The vertex-id universe is fixed at
    partition time: deltas may only reference ids < n (size the graph
    with isolated vertices up front to "add" vertices later).
    """
    add_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    add_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    add_w: Optional[np.ndarray] = None
    rem_src: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    rem_dst: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))

    def symmetrized(self) -> "EdgeDelta":
        """Both directions of every add and removal (for graphs stored
        symmetrized).  No dedup: don't add (u, v) and (v, u) both."""
        w = None if self.add_w is None else np.concatenate([self.add_w] * 2)
        return EdgeDelta(
            add_src=np.concatenate([self.add_src, self.add_dst]),
            add_dst=np.concatenate([self.add_dst, self.add_src]),
            add_w=w,
            rem_src=np.concatenate([self.rem_src, self.rem_dst]),
            rem_dst=np.concatenate([self.rem_dst, self.rem_src]))


def apply_delta(g: Graph, delta: EdgeDelta) -> Graph:
    """Host reference mutation: kept edges in original order, adds
    appended.  ``fold_delta`` on a partition of ``g`` must equal
    ``partition(apply_delta(g, delta), ..., perm=pg.perm)``."""
    keep = np.ones(g.m, bool)
    if len(delta.rem_src):
        rkey = (np.asarray(delta.rem_src, np.int64) * g.n
                + np.asarray(delta.rem_dst, np.int64))
        keep = ~np.isin(g.src.astype(np.int64) * g.n + g.dst, rkey)
    a_src = np.asarray(delta.add_src, np.int64)
    a_dst = np.asarray(delta.add_dst, np.int64)
    src = np.concatenate([g.src[keep], a_src])
    dst = np.concatenate([g.dst[keep], a_dst])
    if g.weight is None and delta.add_w is None:
        return Graph(g.n, src, dst, None)
    w_old = (g.weight if g.weight is not None
             else np.ones(g.m, np.float32))
    a_w = (np.asarray(delta.add_w, np.float32) if delta.add_w is not None
           else np.ones(len(a_src), np.float32))
    return Graph(g.n, src, dst,
                 np.concatenate([w_old[keep], a_w]).astype(np.float32))


def _graph_of(pg: PartitionedGraph) -> Graph:
    """Reconstruct the original-id-space edge list stored in ``pg`` (csr:
    exact original within-worker order; padded: owner-grouped order)."""
    if pg.layout == "csr":
        s_new = np.asarray(pg.all_src, np.int64)
        d_new = np.asarray(pg.all_dst, np.int64)
        w = np.asarray(pg.all_w, np.float32)
    else:
        m = np.asarray(pg.all_mask)
        row = np.nonzero(m)[0]
        s_new = row * pg.n_loc + np.asarray(pg.all_src)[m].astype(np.int64)
        d_new = np.asarray(pg.all_dst)[m].astype(np.int64)
        w = np.asarray(pg.all_w)[m].astype(np.float32)
    return Graph(pg.n, pg.inv_perm[s_new], pg.inv_perm[d_new], w)


def _fold_rebuild(pg: PartitionedGraph, delta: EdgeDelta
                  ) -> PartitionedGraph:
    """Reference fold: materialize the mutated edge list and re-partition
    under the PINNED perm (placement identical, so resident executors
    keep their shapes).  Used for the padded layout and balance="split",
    whose physical shard boundaries are a global function of the loads."""
    g2 = apply_delta(_graph_of(pg), delta)
    return partition(g2, pg.M, tau=pg.tau, layout=pg.layout,
                     balance=pg.balance, split_factor=pg.split_factor,
                     hosts=pg.hosts, perm=pg.perm)


def fold_delta(pg: PartitionedGraph, delta: EdgeDelta) -> PartitionedGraph:
    """Fold a streaming edge delta into the flat csr layout WITHOUT
    re-running ``partition()`` — the serving-path mutation primitive.

    The vertex relabeling (``perm``), worker count, ``n_loc``, ``tau``
    and ``vmask`` are all preserved, so a resident sharded executor built
    on ``pg`` keeps its compiled shapes (modulo edge-count growth, which
    ``core/exec.ShardProfile`` absorbs).  The incremental work is O(E)
    passes plus O(|delta| log |delta|) sorts — never the O(E log E)
    global sorts or the greedy LPT assignment of a fresh ``partition()``:

    * full adjacency: removals are mask-compacted in place (kept edges
      stay owner-grouped in their original relative order), adds are
      counting-sorted by owner and appended to each owner's segment —
      exactly where a fresh stable owner-sort of [kept..., adds...]
      would put them, so the csr arrays match a fresh partition
      BITWISE;
    * Ch_msg (eg): recompacted from the merged adjacency by the new
      mirrored mask (degree flips across tau move edges between the
      channels);
    * mirror csr: kept mirror edges are already (dst_worker, src, dst)-
      sorted; the pool of incoming edges (adds with mirrored sources +
      lo->hi flipped vertices' edges) is sorted alone and merged via
      two searchsorted passes;
    * ``mir_nworkers`` (Theorem-1 counts): copied for untouched
      vertices, recomputed from the merged edges only for sources the
      delta or a tau flip touched;
    * ``pair_counts`` caps: monotone UPPER bound — distinct added
      (worker, dst) pairs increment, removals never decrement.  Caps
      may over-provision after churn but can never under-admit (and an
      under-capped exchange only costs overflow rounds, never
      correctness); an elastic ``GraphService.repartition()`` (or any
      fresh ``partition()``) re-tightens them to exact fresh-partition
      values.

    The padded layout and ``balance="split"`` fall back to the pinned-
    perm rebuild (``_fold_rebuild``).
    """
    if pg.layout != "csr" or pg.balance == "split":
        return _fold_rebuild(pg, delta)
    M, n_loc = pg.M, pg.n_loc
    n_ids = M * n_loc
    perm = pg.perm
    tau_eff = pg.tau

    a_src = perm[np.asarray(delta.add_src, np.int64)]
    a_dst = perm[np.asarray(delta.add_dst, np.int64)]
    a_w = (np.asarray(delta.add_w, np.float32)
           if delta.add_w is not None
           else np.ones(len(a_src), np.float32))
    rkey = None
    if len(delta.rem_src):
        rkey = np.unique(perm[np.asarray(delta.rem_src, np.int64)]
                         * n_ids
                         + perm[np.asarray(delta.rem_dst, np.int64)])
        # endpoint tables + hashed-key bitmap prefilter: the exact
        # (sorted-rkey) probe only runs on edges sharing BOTH endpoints
        # with some removal — np.isin would sort all E keys every fold
        t_src = np.zeros(n_ids, bool)
        t_dst = np.zeros(n_ids, bool)
        t_src[(rkey // n_ids)] = True
        t_dst[(rkey % n_ids)] = True
        _hb = np.uint64(64 - 22)            # 4M-entry bitmap
        h_mul = np.uint64(0x9E3779B97F4A7C15)
        h_bit = np.zeros(1 << 22, bool)
        h_bit[((rkey.astype(np.uint64) * h_mul)
               >> _hb).astype(np.int64)] = True

    def _removed(s, d):
        """Indices into (s, d) of edges matching a removal key."""
        if rkey is None or not len(s):
            return np.zeros(0, np.int64)
        c1 = np.flatnonzero(t_src[s])
        ci = c1[t_dst[d[c1]]]
        ck = s[ci].astype(np.int64) * n_ids + d[ci]
        hh = h_bit[((ck.astype(np.uint64) * h_mul)
                    >> _hb).astype(np.int64)]
        ci, ck = ci[hh], ck[hh]
        p = np.searchsorted(rkey, ck)
        p[p == len(rkey)] = 0           # ck > rkey[-1] there: no match
        return ci[rkey[p] == ck]

    all_src = np.asarray(pg.all_src)          # int32, zero-copy views
    all_dst = np.asarray(pg.all_dst)
    all_w = np.asarray(pg.all_w)
    all_off = np.asarray(pg.all_off, np.int64)
    rem_idx = _removed(all_src, all_dst)
    keep = np.ones(len(all_src), bool)
    keep[rem_idx] = False

    deg_old = np.asarray(pg.deg, np.int64).reshape(-1)
    deg_new = (deg_old
               - np.bincount(all_src[rem_idx], minlength=n_ids)
               + np.bincount(a_src, minlength=n_ids))

    # ---- merged full adjacency: kept edges compact in place, adds
    #      counting-sorted by owner and appended per owner segment ------
    rem_owner = np.searchsorted(all_off, rem_idx, side="right") - 1
    a_owner = a_src // n_loc
    ao = np.argsort(a_owner, kind="stable")
    a_src, a_dst, a_w, a_owner = a_src[ao], a_dst[ao], a_w[ao], a_owner[ao]
    kept_cnt = np.diff(all_off) - np.bincount(rem_owner, minlength=M)
    add_cnt = np.bincount(a_owner, minlength=M)
    ad_off = np.concatenate([[0], np.cumsum(add_cnt)]).astype(np.int64)
    new_off = np.concatenate(
        [[0], np.cumsum(kept_cnt + add_cnt)]).astype(np.int64)
    e_new = int(new_off[-1])
    a_src32 = a_src.astype(np.int32)
    a_dst32 = a_dst.astype(np.int32)
    no_rem = not len(rem_idx)

    def _merge(vals, add, dtype):
        # [kept_0, add_0, kept_1, add_1, ...]: exactly where a fresh
        # stable owner-sort of [kept..., adds...] lands them; segment-
        # wise so the compaction temp stays cache-resident
        out = np.empty(e_new, dtype)
        for w_ in range(M):
            o, kk = new_off[w_], kept_cnt[w_]
            sl = slice(all_off[w_], all_off[w_ + 1])
            out[o:o + kk] = vals[sl] if no_rem else vals[sl][keep[sl]]
            out[o + kk:new_off[w_ + 1]] = add[ad_off[w_]:ad_off[w_ + 1]]
        return out

    na_src = _merge(all_src, a_src32, np.int32)
    na_dst = _merge(all_dst, a_dst32, np.int32)
    na_w = _merge(all_w, a_w, np.float32)

    # ---- pair_counts: monotone upper bound on the caps -----------------
    pair_counts = pg.pair_counts.copy()
    if len(a_src):
        akey = np.unique(a_owner * np.int64(n_ids) + a_dst)
        np.add.at(pair_counts,
                  ((akey // n_ids).astype(np.int64),
                   ((akey % n_ids) // n_loc).astype(np.int64)), 1)

    if int(deg_old.max()) < tau_eff and int(deg_new.max()) < tau_eff:
        # no vertex is mirrored before or after the fold: Ch_msg IS the
        # full adjacency (exactly as in a fresh partition) and every
        # mirror field is the empty sentinel pg already carries
        src_j = jnp.asarray(na_src)
        dst_j = jnp.asarray(na_dst)
        w_j = jnp.asarray(na_w)
        mask_j = jnp.asarray(np.ones(e_new, bool))
        return PartitionedGraph(
            n=pg.n, M=M, n_loc=n_loc, tau=tau_eff, perm=perm,
            inv_perm=pg.inv_perm,
            eg_src=src_j, eg_dst=dst_j, eg_mask=mask_j, eg_w=w_j,
            all_src=src_j, all_dst=dst_j, all_mask=mask_j, all_w=w_j,
            mir_ids=pg.mir_ids, mir_slot_of=pg.mir_slot_of,
            mir_nworkers=pg.mir_nworkers, mir_esrc=pg.mir_esrc,
            mir_edst=pg.mir_edst, mir_emask=pg.mir_emask,
            mir_ew=pg.mir_ew,
            deg=jnp.asarray(deg_new.astype(np.int32).reshape(M, n_loc)),
            vmask=pg.vmask,
            layout="csr", eg_off=new_off, all_off=new_off,
            mir_eoff=pg.mir_eoff,
            balance=pg.balance, split_factor=pg.split_factor, M_phys=M,
            pair_counts=pair_counts, hosts=pg.hosts)

    mirrored_old = deg_old >= tau_eff
    mirrored_new = deg_new >= tau_eff
    flip_up = mirrored_new & ~mirrored_old

    # ---- Ch_msg: recompact from the merged adjacency -------------------
    lo_e = ~mirrored_new[na_src]
    eg_off_n = np.concatenate(
        [[0], np.cumsum(np.bincount((na_src // n_loc)[lo_e],
                                    minlength=M))]).astype(np.int64)

    # ---- mirror csr: merge kept (already sorted) with the pool ---------
    mir_ids_old = np.asarray(pg.mir_ids, np.int64)
    m_esrc_old = np.asarray(pg.mir_esrc, np.int64)
    m_gsrc_old = (mir_ids_old[m_esrc_old] if len(m_esrc_old)
                  else np.zeros(0, np.int64))
    m_gdst_old = np.asarray(pg.mir_edst, np.int64)
    m_w_old = np.asarray(pg.mir_ew, np.float32)
    rem_mir = np.zeros(len(m_gsrc_old), bool)
    rem_mir[_removed(m_gsrc_old, m_gdst_old)] = True
    flip_dn_src = mirrored_old & ~mirrored_new
    keep_mir = ~rem_mir & ~flip_dn_src[m_gsrc_old]

    eg_src_old = np.asarray(pg.eg_src, np.int64)
    eg_dst_old = np.asarray(pg.eg_dst, np.int64)
    eg_w_old = np.asarray(pg.eg_w, np.float32)
    # removal membership only matters on the few flipped-up sources
    fu_idx = np.flatnonzero(flip_up[eg_src_old])
    fu_keep = np.ones(len(fu_idx), bool)
    fu_keep[_removed(eg_src_old[fu_idx], eg_dst_old[fu_idx])] = False
    up_idx = fu_idx[fu_keep]
    a_hi = mirrored_new[a_src]
    p_gsrc = np.concatenate([eg_src_old[up_idx], a_src[a_hi]])
    p_gdst = np.concatenate([eg_dst_old[up_idx], a_dst[a_hi]])
    p_w = np.concatenate([eg_w_old[up_idx], a_w[a_hi]]).astype(np.float32)
    # pool sorted by the mirror key (dst worker, src, dst); lexsort is
    # stable so old-before-add tie order (= fresh partition order) holds
    porder = np.lexsort((p_gdst, p_gsrc, p_gdst // n_loc))
    p_gsrc, p_gdst, p_w = p_gsrc[porder], p_gdst[porder], p_w[porder]

    def _mkey(s, d):
        # composite (dst_worker, src, dst) key; fits int64 while
        # M * n_ids^2 < 2^63 (n ~ 3e8 at M=64) — far beyond our scale
        return (d // n_loc) * (n_ids * n_ids) + s * n_ids + d

    kk = _mkey(m_gsrc_old[keep_mir], m_gdst_old[keep_mir])
    pk = _mkey(p_gsrc, p_gdst)
    n_k, n_p = len(kk), len(pk)
    pos_kept = (np.arange(n_k, dtype=np.int64)
                + np.searchsorted(pk, kk, side="left"))
    pos_pool = (np.arange(n_p, dtype=np.int64)
                + np.searchsorted(kk, pk, side="right"))
    m_gsrc = np.empty(n_k + n_p, np.int64)
    m_gdst = np.empty(n_k + n_p, np.int64)
    m_w = np.empty(n_k + n_p, np.float32)
    m_gsrc[pos_kept], m_gsrc[pos_pool] = m_gsrc_old[keep_mir], p_gsrc
    m_gdst[pos_kept], m_gdst[pos_pool] = m_gdst_old[keep_mir], p_gdst
    m_w[pos_kept], m_w[pos_pool] = m_w_old[keep_mir], p_w
    m_downer = m_gdst // n_loc
    hb_n = np.searchsorted(m_downer, np.arange(M + 1)).astype(np.int64)

    mir_vertex_ids = np.flatnonzero(mirrored_new)
    n_mir = max(len(mir_vertex_ids), 1)
    mir_idx = np.full(n_ids, -1, np.int64)
    mir_idx[mir_vertex_ids] = np.arange(len(mir_vertex_ids))
    mir_ids_arr = np.full(n_mir, n_ids, np.int32)
    mir_ids_arr[:len(mir_vertex_ids)] = mir_vertex_ids

    # ---- Theorem-1 mirror counts: copy untouched, recount touched ------
    touched = np.zeros(n_ids, bool)
    touched[m_gsrc_old[rem_mir]] = True
    touched[p_gsrc] = True
    nworkers = np.zeros(n_mir, np.int64)
    common = mirrored_old & mirrored_new & ~touched
    cids = np.flatnonzero(common)
    if len(cids):
        old_slot = np.asarray(pg.mir_slot_of, np.int64).reshape(-1)
        nworkers[mir_idx[cids]] = np.asarray(
            pg.mir_nworkers, np.int64)[old_slot[cids]]
    am = touched[m_gsrc]
    if am.any():
        pair = np.unique(m_gsrc[am] * np.int64(M) + m_downer[am])
        cnt = np.bincount((pair // M).astype(np.int64), minlength=n_ids)
        aff = np.flatnonzero(touched & mirrored_new)
        nworkers[mir_idx[aff]] = cnt[aff]

    return PartitionedGraph(
        n=pg.n, M=M, n_loc=n_loc, tau=tau_eff, perm=perm,
        inv_perm=pg.inv_perm,
        eg_src=jnp.asarray(na_src[lo_e]),
        eg_dst=jnp.asarray(na_dst[lo_e]),
        eg_mask=jnp.asarray(np.ones(int(lo_e.sum()), bool)),
        eg_w=jnp.asarray(na_w[lo_e]),
        all_src=jnp.asarray(na_src),
        all_dst=jnp.asarray(na_dst),
        all_mask=jnp.asarray(np.ones(e_new, bool)),
        all_w=jnp.asarray(na_w),
        mir_ids=jnp.asarray(mir_ids_arr),
        mir_slot_of=jnp.asarray(mir_idx.astype(np.int32)
                                .reshape(M, n_loc)),
        mir_nworkers=jnp.asarray(nworkers),
        mir_esrc=jnp.asarray(mir_idx[m_gsrc].astype(np.int32)),
        mir_edst=jnp.asarray(m_gdst.astype(np.int32)),
        mir_emask=jnp.asarray(np.ones(n_k + n_p, bool)),
        mir_ew=jnp.asarray(m_w),
        deg=jnp.asarray(deg_new.astype(np.int32).reshape(M, n_loc)),
        vmask=pg.vmask,
        layout="csr", eg_off=eg_off_n, all_off=new_off, mir_eoff=hb_n,
        balance=pg.balance, split_factor=pg.split_factor, M_phys=M,
        pair_counts=pair_counts, hosts=pg.hosts,
    )

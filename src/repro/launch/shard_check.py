"""Sharded-vs-single-device parity + memory harness.

Runs algorithm x layout x backend cells of the conformance matrix through
the sharded executor at each requested device count and compares against
the single-device batched simulation:

* integer / min / max results (hashmin, sssp, sv, msf labels, attribute
  gather) must be **bitwise identical**;
* PageRank (float sum combine) must agree to tight tolerance (the
  exchange changes float reduction order, nothing else);
* every ``msgs_*`` / ``per_worker_*`` statistic must be integer-exact;
* the dense sharded Ch_msg must actually lower to an ``all-to-all``
  collective (checked in the compiled HLO);
* the routed-exchange memory contract must hold: no compiled sharded
  channel may all-reduce / all-gather an operand of >= n_pad elements
  (``check_routed_memory`` — the destination-routed exchange exists
  precisely to kill the per-device O(n) replicated buffers);
* masked request lanes must never leak into gathered values
  (``check_masked_lanes`` — sharded == unsharded bitwise, masked = 0);
* on a 2-D ``(hosts, devices)`` mesh every routed join must compile to
  TWO distinct all-to-all levels — replica groups of size T (intra-host)
  AND size H (cross-host) — with the no-replicated-buffer contract
  holding at both levels (``check_hier_levels``), and explicit
  per-level caps far below the traffic must still produce bitwise
  results via overflow rounds, including a hot destination on the host
  axis (``check_hier_caps``).

Device counts are ints (1-D worker mesh) or ``(hosts, per_host)``
tuples (hierarchical mesh; ``HxT`` on the command line, e.g.
``--devices 8 2x4``).

Run as a module (it forces the host device count BEFORE importing jax, so
it works on a plain CPU machine and in CI):

    PYTHONPATH=src python -m repro.launch.shard_check --suite tier1 \
        --out shard-parity.json

``--suite tier1`` is the consolidated fast profile driven by the tier-1
test suite in ONE subprocess; ``--suite full`` is the nightly
6 algos x 2 layouts x 2 backends x 5 balance modes x devices
{1,2,8,(2,4)} matrix, run sequential AND through the double-buffered
pipeline (the reference is always the sequential single-device run).
Every balance sweep also prints the cross-device message fraction of
its partition (``exec.crossness_report``).  Explicit
``--devices/--algos/--balance/--layouts`` (+ ``--pipeline``) compose a
custom matrix instead.  Exits non-zero on the first violated cell.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

from repro.launch.xla_flags import force_host_devices


ALGOS = ("hashmin", "pagerank", "sssp", "sv", "msf", "attr_bcast")


def _dev_tag(devices) -> str:
    """Cell-label spelling of a device count: ``8`` or ``2x4``."""
    if isinstance(devices, tuple):
        return "x".join(str(d) for d in devices)
    return str(devices)


def _flat_devices(devices) -> int:
    """Host device count a mesh spec needs: H*T for tuples."""
    if isinstance(devices, tuple):
        out = 1
        for d in devices:
            out *= int(d)
        return out
    return int(devices)


def run_matrix(algos=ALGOS, layouts=("padded", "csr"),
               backends=("dense", "pallas"), device_counts=(1, 2, 8),
               n=180, M=8, tau=8, seed=0, balance="hash",
               split_factor=1.1, pipeline=False):
    """Returns (report dict, ok flag).  Call only after jax sees enough
    devices (``xla_flags.force_host_devices`` before the first import).
    ``balance`` selects the partitioner mode; ``"split"`` requires the csr
    layout, so padded cells are skipped there.  ``pipeline=True`` runs the
    SHARDED side through the double-buffered executor while the reference
    stays sequential — proving the pipeline keeps the same parity
    contract (bitwise for min/max/int, tolerance for float sums, stats
    integer-exact)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.api import Engine, config_of
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    if balance == "split":
        layouts = tuple(lay for lay in layouts if lay == "csr")
    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    pgs = {lay: partition(g, M, tau=tau, seed=seed, layout=lay,
                          balance=balance, split_factor=split_factor)
           for lay in layouts}

    def run_algo(algo, pg, backend, devices, pipe=False):
        # one Engine per cell: the config IS the cell coordinates
        eng = Engine(config_of(pg, backend=backend, devices=devices,
                               pipeline=pipe))
        if algo == "attr_bcast":
            attr = jnp.arange(pg.n_pad, dtype=jnp.float32
                              ).reshape(pg.M, pg.n_loc) * 3
            res = eng.run("attr_bcast", pg, attr=attr)
            return {"exact": np.asarray(res.state)}, {}, res.stats, 2
        params = {"pagerank": dict(n_iters=8, tol=1e-12),
                  "sssp": dict(source=int(pg.perm[0]))}.get(algo, {})
        res = eng.run(algo, pg, **params)
        if algo == "pagerank":
            return ({}, {"pr": np.asarray(res.state)}, res.stats,
                    int(res.n_supersteps))
        if algo == "msf":
            lab, tw, ne = res.state
            return ({"exact": np.asarray(lab), "ne": int(ne)},
                    {"tw": float(tw)}, res.stats, int(res.n_supersteps))
        return ({"exact": np.asarray(res.state)}, {}, res.stats,
                int(res.n_supersteps))

    report = {"n": n, "M": M, "tau": tau, "balance": balance,
              "pipeline": bool(pipeline), "cells": {}, "crossness": {}}
    # the locality number the balance mode optimizes: cross-device /
    # cross-host message fraction from the honest pair_counts accounting
    from repro.core.exec import crossness_report
    Dmax = max(_flat_devices(d) for d in device_counts)
    for lay, pg in pgs.items():
        cr = crossness_report(pg, Dmax if M % Dmax == 0 else None)
        report["crossness"][f"{lay}/{balance}"] = cr
        line = (f"[shard_check] crossness {lay}/{balance}: "
                f"cross-worker={cr['cross_worker_frac']:.3f}")
        if "cross_device_frac" in cr:
            line += (f" cross-device={cr['cross_device_frac']:.3f}"
                     f" (D={cr['D']})")
        print(line)
    ok = True
    pipe_tag = "/pipeline" if pipeline else ""
    for algo in algos:
        for lay in layouts:
            for be in backends:
                pg = pgs[lay]
                # the reference is ALWAYS the sequential single-device run
                ref_e, ref_a, ref_s, ref_n = run_algo(algo, pg, be, None)
                for D in device_counts:
                    name = (f"{algo}/{lay}/{be}/{balance}/"
                            f"devices={_dev_tag(D)}{pipe_tag}")
                    errs = []
                    e, a, s, nss = run_algo(algo, pg, be, D,
                                            pipe=pipeline)
                    if nss != ref_n:
                        errs.append(f"supersteps {nss} != {ref_n}")
                    for k in ref_e:
                        if not np.array_equal(np.asarray(e[k]),
                                              np.asarray(ref_e[k])):
                            errs.append(f"result {k!r} not bitwise equal")
                    for k in ref_a:
                        if not np.allclose(a[k], ref_a[k],
                                           rtol=1e-5, atol=1e-7):
                            errs.append(f"result {k!r} out of tolerance")
                    if set(s) != set(ref_s):
                        errs.append("stats keys differ")
                    else:
                        for k in ref_s:
                            if not np.array_equal(np.asarray(s[k]),
                                                  np.asarray(ref_s[k])):
                                errs.append(f"stat {k!r} differs: "
                                            f"{np.asarray(s[k])} vs "
                                            f"{np.asarray(ref_s[k])}")
                    report["cells"][name] = errs
                    ok &= not errs
                    print(f"[shard_check] {name}: "
                          + ("OK" if not errs else "; ".join(errs)))
    return report, ok


def _test_graph(n, M, tau, layout="csr", balance="hash"):
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    return partition(g, M, tau=tau, seed=0, layout=layout, balance=balance,
                     split_factor=1.1)


def check_all_to_all(n=180, M=8, tau=8, devices=8) -> bool:
    """The sharded Ch_msg join must compile to a real all-to-all."""
    from repro.core import exec as exec_mod
    from repro.core.plan import identity_of
    import jax.numpy as jnp

    pg = _test_graph(n, M, tau)

    def make_step(gr):
        def step(state, i):
            from repro.core.channels import broadcast
            inbox, stats = broadcast(gr, state, gr.vmask, op="min")
            return jnp.minimum(state, inbox), gr.gany(inbox < state), stats
        return step

    state0 = jnp.where(pg.vmask, pg.local_ids().astype(jnp.int32),
                       identity_of("min", jnp.int32))
    fn, args, _ = exec_mod.build_sharded(pg, make_step, state0, 3,
                                         devices=devices)
    txt = fn.lower(*args).compile().as_text()
    found = "all-to-all" in txt
    print(f"[shard_check] dense join lowers to all-to-all: {found}")
    return found


# ---------------------------------------------------------------------------
# routed-exchange memory contract
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"[a-z][a-z0-9]*\[([0-9,]*)\]")


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    out = 1
    for d in dims.split(","):
        out *= int(d)
    return out


def collective_operand_elems(hlo_text: str) -> dict:
    """Per collective kind, the largest result-operand element count in a
    compiled HLO module — the needle the memory gate looks for: the old
    executor all-reduced (n_pad,) scatter buffers and all-gathered the
    full value vector; the routed exchange must leave only scalar / (M,)
    stats reductions.  Async spellings (``all-reduce-start`` etc.) and
    the reduce-scatter decomposition fold into their base kind so the
    gate cannot pass vacuously on backends that pipeline collectives."""
    worst = {"all-reduce": 0, "all-gather": 0, "all-to-all": 0}
    spellings = [(f" {kind}{suffix}(", kind)
                 for kind in worst for suffix in ("", "-start")]
    spellings += [(" reduce-scatter(", "all-reduce"),
                  (" reduce-scatter-start(", "all-reduce")]
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for tag, kind in spellings:
            if tag not in line:
                continue
            result = line.split("=", 1)[1].split(tag)[0]
            for m in _SHAPE_RE.finditer(result):
                worst[kind] = max(worst[kind], _shape_elems(m.group(1)))
    return worst


def _compiled_channel_programs(pg, devices):
    """Compile one representative sharded program per gated join family.
    Returns {name: jax Compiled}."""
    import jax.numpy as jnp
    from repro.core import exec as exec_mod
    from repro.core.channels import broadcast, gather, scatter_state
    from repro.core.plan import identity_of

    imax = identity_of("min", jnp.int32)
    ids = pg.local_ids().astype(jnp.int32)
    state0 = jnp.where(pg.vmask, ids, imax)

    def bcast_step(backend):
        def make_step(g):
            def step(state, i):
                inbox, stats = broadcast(g, state, g.vmask, op="min",
                                         backend=backend)
                return jnp.minimum(state, inbox), g.gany(inbox < state), stats
            return step
        return make_step

    def scatter_step(g):
        # S-V-style runtime-target scatter: targets are algorithm state
        def step(state, i):
            new, stats = scatter_state(g, state, state, state, g.vmask,
                                       "min")
            return new, g.gall(new == state), stats
        return step

    def gather_step(g):
        # request-respond pointer chase (the Ch_req two-round trip)
        def step(state, i):
            got, stats = gather(g, state, state, g.vmask)
            new = jnp.minimum(state, got)
            return new, g.gall(new == state), stats
        return step

    progs = {}
    for name, mk, kinds in (
            ("broadcast_dense", bcast_step("dense"), ()),
            ("broadcast_plan", bcast_step("pallas"),
             exec_mod.broadcast_plan_kinds("pallas")),
            ("runtime_scatter", scatter_step, ()),
            ("request_respond", gather_step, ())):
        fn, args, _ = exec_mod.build_sharded(pg, mk, state0, 3,
                                             devices=devices,
                                             plan_kinds=kinds)
        progs[name] = fn.lower(*args).compile()
    return progs


def routed_memory_report(pg, devices: int) -> dict:
    """Compile the gated channel programs and record, per program, the
    worst collective operand (elements) and the per-device compiled
    buffer stats (bytes) — the numbers the bench-graph artifact tracks."""
    report = {"n_pad": int(pg.n_pad), "devices": int(devices),
              "programs": {}}
    for name, compiled in _compiled_channel_programs(pg, devices).items():
        worst = collective_operand_elems(compiled.as_text())
        entry = {"collective_max_elems": worst}
        try:
            ma = compiled.memory_analysis()
            entry["temp_bytes"] = int(ma.temp_size_in_bytes)
            entry["argument_bytes"] = int(ma.argument_size_in_bytes)
            entry["output_bytes"] = int(ma.output_size_in_bytes)
            entry["peak_live_bytes"] = int(ma.temp_size_in_bytes
                                           + ma.output_size_in_bytes)
        except Exception:  # backend without buffer stats
            pass
        report["programs"][name] = entry
    return report


def check_routed_memory(n=180, M=8, tau=8, devices=8,
                        balance="hash") -> dict:
    """The acceptance gate: at D=8 no sharded channel may all-reduce or
    all-gather an operand of >= n_pad elements — the replicated-buffer
    wall the destination-routed exchange removes.  (all-to-all operands
    are the routed exchange itself and scale with the caps, not n.)"""
    pg = _test_graph(n, M, tau, balance=balance)
    rep = routed_memory_report(pg, devices)
    ok = True
    for name, entry in rep["programs"].items():
        worst = entry["collective_max_elems"]
        bad = max(worst["all-reduce"], worst["all-gather"])
        cell_ok = bad < pg.n_pad
        ok &= cell_ok
        print(f"[shard_check] routed-memory {name}: worst all-reduce/"
              f"all-gather operand {bad} elems vs n_pad {pg.n_pad}: "
              + ("OK" if cell_ok else "REPLICATED BUFFER"))
    rep["ok"] = bool(ok)
    return rep


# ---------------------------------------------------------------------------
# hierarchical (host, device) mesh contracts
# ---------------------------------------------------------------------------

_GROUP_RE = re.compile(r"\{([0-9]+(?:,[0-9]+)*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[([0-9]+),([0-9]+)\]<=")


def all_to_all_group_sizes(hlo_text: str) -> set:
    """Replica-group sizes of every ``all-to-all`` in a compiled module.
    On a 2-D mesh the intra-host level shows groups of size T
    (``{{0,1,2,3},{4,5,6,7}}`` at (2,4)) and the cross-host level groups
    of size H (``{{0,4},{1,5},...}``); the iota spelling
    (``[groups,size]<=[...]``) is folded in for newer jaxlibs."""
    sizes = set()
    for line in hlo_text.splitlines():
        if "all-to-all" not in line or "replica_groups=" not in line:
            continue
        groups = line.split("replica_groups=", 1)[1]
        m = _IOTA_GROUPS_RE.search(line)
        if m:
            sizes.add(int(m.group(2)))
            continue
        if groups.startswith("{{"):
            body = groups[1:groups.index("}}") + 1]
            for g in _GROUP_RE.finditer(body):
                sizes.add(g.group(1).count(",") + 1)
    return sizes


def check_hier_levels(n=180, M=8, tau=8, hier=(2, 4)) -> dict:
    """The 2-D acceptance gate: every compiled sharded channel program on
    a ``(H, T)`` mesh must run TWO distinct all-to-all levels — replica
    groups of size T (the intra-host leg, where the per-level combine /
    dedup happens) AND of size H (the cross-host leg carrying only the
    combined residue) — and at neither level may any all-reduce /
    all-gather touch an operand of >= n_pad elements (the same
    replicated-buffer wall as the 1-D gate, now per level)."""
    H, T = hier
    pg = _test_graph(n, M, tau)
    rep = {"hier": [H, T], "n_pad": int(pg.n_pad), "programs": {}}
    ok = True
    for name, compiled in _compiled_channel_programs(pg, hier).items():
        txt = compiled.as_text()
        sizes = all_to_all_group_sizes(txt)
        two = {H, T} <= sizes
        worst = collective_operand_elems(txt)
        bad = max(worst["all-reduce"], worst["all-gather"])
        small = bad < pg.n_pad
        rep["programs"][name] = {
            "all_to_all_group_sizes": sorted(sizes),
            "collective_max_elems": worst,
            "two_levels": bool(two),
            "no_replicated_buffer": bool(small)}
        ok &= two and small
        print(f"[shard_check] hier-levels {name} @ {H}x{T}: all-to-all "
              f"group sizes {sorted(sizes)}, worst all-reduce/all-gather "
              f"operand {bad} vs n_pad {pg.n_pad}: "
              + ("OK" if two and small else
                 ("MISSING LEVEL" if not two else "REPLICATED BUFFER")))
    rep["ok"] = bool(ok)
    return rep


def check_gspmm_hier(n=180, M=8, tau=8, F=4, hier=(2, 4)) -> dict:
    """The vector-payload 2-D gate: a gSpMM channel join carrying an F>1
    feature block, compiled on a ``(H, T)`` mesh, must run the SAME two
    all-to-all levels as the scalar channels — replica groups of size T
    (intra-host leg, per-level combine) and of size H (cross-host leg,
    combined residue only).  The ``(lanes, F)`` blocks ride the routed
    exchange; they must not change its topology.  And no all-reduce /
    all-gather may touch a >= n_pad-element operand — the
    replicated-buffer wall, which an F-block regression would blow
    through F times harder."""
    import numpy as np

    import jax.numpy as jnp

    from repro.core import exec as exec_mod
    from repro.core import gspmm

    H, T = hier
    pg = _test_graph(n, M, tau)
    feats = jnp.asarray(np.random.RandomState(0)
                        .randn(pg.M, pg.n_loc, F).astype(np.float32))
    rep = {"hier": [H, T], "F": int(F), "n_pad": int(pg.n_pad),
           "programs": {}}
    ok = True
    for name, backend, kinds in (
            ("gspmm_dense", "dense", ()),
            ("gspmm_plan", "pallas",
             exec_mod.broadcast_plan_kinds("pallas"))):
        def mk(g, be=backend):
            def fn(x):
                return gspmm.gspmm_stats(g, "u_mul_e_sum", x, backend=be)
            return fn
        fn, arrays = exec_mod.build_apply(pg, mk, (feats,), devices=hier,
                                          plan_kinds=kinds)
        txt = fn.lower(arrays, (feats,)).compile().as_text()
        sizes = all_to_all_group_sizes(txt)
        two = {H, T} <= sizes
        worst = collective_operand_elems(txt)
        bad = max(worst["all-reduce"], worst["all-gather"])
        small = bad < pg.n_pad
        rep["programs"][name] = {
            "all_to_all_group_sizes": sorted(sizes),
            "collective_max_elems": worst,
            "two_levels": bool(two),
            "no_replicated_buffer": bool(small)}
        ok &= two and small
        print(f"[shard_check] gspmm F={F} {name} @ {H}x{T}: all-to-all "
              f"group sizes {sorted(sizes)}, worst all-reduce/all-gather "
              f"operand {bad} vs n_pad {pg.n_pad}: "
              + ("OK" if two and small else
                 ("MISSING LEVEL" if not two else "REPLICATED BUFFER")))
    rep["ok"] = bool(ok)
    return rep


def check_hier_caps(n=160, M=8, hier=(2, 4)) -> bool:
    """Per-level cap overflow regression: drive the raw routed joins on a
    2-D mesh with explicit ``(cap1, cap2)`` caps far below the traffic —
    every worker funnels most lanes at vertices owned by ONE worker, so
    the destination is hot on the host axis too and the inter-host leg
    must take multiple overflow rounds — and require bitwise parity with
    the dense reference (masked lanes exactly 0), sequential and
    pipelined.  This is the 2-D twin of the 1-D cap contract: a cap is a
    round size, never a truncation."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import exec as exec_mod

    H, T = hier
    pg = _test_graph(n, M, tau=8)
    rng = np.random.RandomState(7)
    R = 33  # lanes per worker: column buckets far exceed an 8-lane cap
    t_np = np.where(
        rng.rand(pg.M, R) < 0.8,
        rng.randint(0, pg.n_loc, (pg.M, R)),          # hot: worker 0
        rng.randint(0, pg.n_pad, (pg.M, R))).astype(np.int32)
    m_np = rng.rand(pg.M, R) > 0.25
    t_np[:, ::5] = 0  # masked lanes alias a real hot vertex
    m_np[:, ::5] = False
    v_np = (rng.randint(1, 1 << 20, (pg.M, R))).astype(np.int32)
    targets, mask = jnp.asarray(t_np), jnp.asarray(m_np)
    vals = jnp.asarray(v_np)
    attr = jnp.asarray(
        rng.randint(1, 1 << 20, (pg.M, pg.n_loc)).astype(np.int32))

    ident = np.iinfo(np.int32).max
    ref_sc = np.full(pg.n_pad + 1, ident, np.int32)
    np.minimum.at(ref_sc, np.where(m_np, t_np, pg.n_pad).reshape(-1),
                  v_np.reshape(-1))
    ref_sc = ref_sc[:pg.n_pad].reshape(pg.M, pg.n_loc)
    ref_ft = np.where(m_np, np.asarray(attr).reshape(-1)[t_np], 0)

    def mk_scatter(g):
        if not isinstance(g, exec_mod.ShardedGraph):
            return lambda t, v, m: (jnp.asarray(ref_sc), {})

        def fn(t, v, m):
            out = exec_mod._routed_scatter_combine(
                g, t.reshape(-1), v.reshape(-1), m.reshape(-1), "min",
                cap=(8, 8))
            return out.reshape(g.m_loc, g.n_loc), {}
        return fn

    def mk_fetch(g):
        if not isinstance(g, exec_mod.ShardedGraph):
            return lambda a, t, m: (jnp.asarray(ref_ft), {})

        def fn(a, t, m):
            got = exec_mod._routed_fetch(g, a, t.reshape(-1),
                                         m.reshape(-1), cap=(8, 8))
            return got.reshape(-1, t.shape[1]), {}
        return fn

    ok = True
    for pipe in (False, True):
        out_sc, _ = exec_mod.apply_sharded(
            pg, mk_scatter, (targets, vals, mask), devices=hier,
            pipeline=pipe)
        sc_ok = bool(np.array_equal(np.asarray(out_sc), ref_sc))
        out_ft, _ = exec_mod.apply_sharded(
            pg, mk_fetch, (attr, targets, mask), devices=hier,
            pipeline=pipe)
        ft_ok = bool(np.array_equal(np.asarray(out_ft), ref_ft))
        ok &= sc_ok and ft_ok
        tag = "pipeline" if pipe else "sequential"
        print(f"[shard_check] hier-caps @ {H}x{T} cap=(8,8) {tag}: "
              f"scatter {'OK' if sc_ok else 'MISMATCH'}, "
              f"fetch {'OK' if ft_ok else 'MISMATCH'}")
    return ok


def check_masked_lanes(n=160, M=8, devices=(8,)) -> bool:
    """Masked request lanes must never leak into gathered values: the
    sharded Ch_req output is bitwise identical to the unsharded channel
    for dedup on AND off, and masked lanes hold exactly the reference
    fill (0) — even when the masked target id aliases a real vertex."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import exec as exec_mod
    from repro.core.channels import gather, gather_edges

    ok = True
    # csr covers both Ch_req shapes: the row-shaped path never touches
    # edge arrays (layout-independent), the edge-shaped one rides the csr
    # adjacency
    for layout in ("csr",):
        pg = _test_graph(n, M, tau=None, layout=layout)
        rng = np.random.RandomState(3)
        vals = jnp.asarray(rng.randn(pg.M, pg.n_loc).astype(np.float32)
                           + 1.0)  # nonzero everywhere: 0 == masked fill
        R = 17
        targets = rng.randint(0, pg.n_pad, (pg.M, R)).astype(np.int32)
        # masked lanes deliberately alias vertex 0 / hot vertices
        targets[:, ::3] = 0
        mask = jnp.asarray(rng.rand(pg.M, R) > 0.4)
        tj = jnp.asarray(targets)

        for dedup in (True, False):
            ref, _ = gather(pg, vals, tj, mask, dedup=dedup)
            ref = np.asarray(ref)
            masked_zero = bool((ref[~np.asarray(mask)] == 0).all())
            ok &= masked_zero
            for D in devices:
                def mk(g, dd=dedup):
                    return lambda v, t, m: gather(g, v, t, m, dedup=dd)
                out, _ = exec_mod.apply_sharded(pg, mk, (vals, tj, mask),
                                                devices=D)
                same = bool(np.array_equal(np.asarray(out), ref))
                ok &= same
                print(f"[shard_check] masked-lanes gather {layout} "
                      f"dedup={dedup} devices={D}: "
                      + ("OK" if same and masked_zero else "LEAK"))

        # edge-shaped twin on the csr layout: targets/mask derived
        # lane-for-lane from the (device-sliced) adjacency so the same
        # formula runs identically unsharded and per device
        if layout == "csr":
            def lanes(dst, emask):
                t = (dst * 37 + 13) % pg.n_pad     # arbitrary alias ids
                m = emask & ((dst * 31 + 7) % 5 > 1)
                return t, m
            for dedup in (True, False):
                def mk(g, dd=dedup):
                    def fn(v):
                        t, m = lanes(g.all_dst, g.all_mask)
                        return gather_edges(g, v, t, m, dedup=dd)
                    return fn
                ref, _ = mk(pg)(vals)
                ref = np.asarray(ref)
                t_np, m_np = lanes(np.asarray(pg.all_dst),
                                   np.asarray(pg.all_mask))
                ok &= bool((ref[~m_np] == 0).all())
                for D in devices:
                    out, _ = exec_mod.apply_sharded(pg, mk, (vals,),
                                                    devices=D)
                    bounds = exec_mod.device_edge_bounds(pg, D)["all"]
                    counts = np.diff(bounds)
                    cap = out.shape[0] // D
                    flat = np.concatenate(
                        [np.asarray(out)[d * cap:d * cap + int(counts[d])]
                         for d in range(D)])
                    same = bool(np.array_equal(flat, ref))
                    ok &= same
                    print(f"[shard_check] masked-lanes gather_edges "
                          f"dedup={dedup} devices={D}: "
                          + ("OK" if same else "LEAK"))
    return ok


# ---------------------------------------------------------------------------
# suites
# ---------------------------------------------------------------------------

def _suite_cells(suite: str):
    """Matrix slices per suite: (algos, layouts, backends, devices,
    balance, pipeline) tuples."""
    if suite == "tier1":
        # one cell per join-family x regime: the pallas row covers every
        # algorithm at one-worker-per-device, the devices=2 cells pin the
        # general m_loc>1 collectives, split covers shard-crossing routes,
        # padded the non-csr edge slicing.  Every row also runs the same
        # traffic through the hierarchical (2,4) mesh — the 2-D cells
        # must match the SAME sequential single-device reference the 1-D
        # cells match, which pins 2-D == 1-D bitwise / integer-exact.
        # The pipeline=True rows prove the double-buffered executor keeps
        # the identical parity contract (every algorithm + a dense
        # m_loc>1 cell + split).  Nightly runs the full matrix, pipelined
        # and sequential, plus the (1,8)/(2,4)/(4,2) hier sweep.
        return [
            (ALGOS, ("csr",), ("pallas",), (8, (2, 4)), "hash", False),
            (ALGOS, ("csr",), ("pallas",), (8, (2, 4)), "hash", True),
            (("sv",), ("csr",), ("dense",), (2, (2, 4)), "hash", False),
            (("sv",), ("csr",), ("dense",), (2, (2, 4)), "hash", True),
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)), "split",
             False),
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)), "split",
             True),
            # the PR-10 partitioner modes: locality refinement and
            # mega-hub vertex-cut ride the same csr/pallas row
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)),
             "edges+refine", False),
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)),
             "edges+refine", True),
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)),
             "vertex-cut", False),
            (("hashmin",), ("csr",), ("pallas",), (8, (2, 4)),
             "vertex-cut", True),
        ]
    if suite == "hier":
        # the hierarchical conformance axis: every algorithm on every
        # (hosts, per_host) factorization of 8 devices — (1,8) pins the
        # degenerate one-host mesh to the 1-D semantics, (2,4)/(4,2) the
        # two proper hierarchies — sequential and pipelined, all against
        # the sequential single-device reference (so all factorizations
        # agree bitwise with each other and with 1-D D=8)
        return [
            (ALGOS, ("csr",), ("pallas",), ((1, 8), (2, 4), (4, 2)),
             "hash", False),
            (ALGOS, ("csr",), ("pallas",), ((1, 8), (2, 4), (4, 2)),
             "hash", True),
        ]
    if suite == "full":
        cells = []
        for pipe in (False, True):
            cells += [
                (ALGOS, ("padded", "csr"), ("dense", "pallas"), (1, 2, 8),
                 "hash", pipe),
                (ALGOS, ("csr",), ("dense", "pallas"),
                 (1, 2, 8, (2, 4)), "edges", pipe),
                (ALGOS, ("csr",), ("dense", "pallas"),
                 (1, 2, 8, (2, 4)), "split", pipe),
                (ALGOS, ("csr",), ("pallas",), (1, 8, (2, 4)),
                 "edges+refine", pipe),
                (ALGOS, ("csr",), ("pallas",), (1, 8, (2, 4)),
                 "vertex-cut", pipe),
            ]
        return cells
    raise ValueError(f"unknown suite {suite!r}")


def _parse_devices(spec: str):
    """``8`` -> 8 (1-D mesh); ``2x4`` -> (2, 4) (hierarchical mesh)."""
    if "x" in spec:
        h, t = spec.split("x", 1)
        return (int(h), int(t))
    return int(spec)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("tier1", "hier", "full"),
                    default=None,
                    help="consolidated profiles (matrix + HLO + memory + "
                         "masked-lane checks in ONE process); overrides "
                         "the explicit matrix flags")
    # 1 = degenerate one-device mesh, 2 = several workers per device
    # (m_loc > 1 with real collectives), 8 = one worker per device,
    # HxT (e.g. 2x4) = hierarchical (host, device) mesh
    ap.add_argument("--devices", type=_parse_devices, nargs="+",
                    default=[1, 2, 8])
    ap.add_argument("--algos", nargs="+", default=list(ALGOS))
    ap.add_argument("--n", type=int, default=180)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--balance", nargs="+", default=["hash"],
                    help="partition balance modes to sweep (hash / edges "
                         "/ edges+refine / split / vertex-cut; split runs "
                         "csr cells only)")
    ap.add_argument("--layouts", nargs="+", default=["padded", "csr"])
    ap.add_argument("--pipeline", action="store_true",
                    help="run the sharded side through the "
                         "double-buffered pipeline (explicit-matrix mode; "
                         "the suites sweep both on their own)")
    ap.add_argument("--skip-hlo-check", action="store_true",
                    help="skip the dense all-to-all HLO assertion (it "
                         "only applies to worker-aligned meshes)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    force_host_devices(
        8 if args.suite else max(_flat_devices(d) for d in args.devices),
        default_platform="cpu")

    report = {"cells": {}}
    ok = True
    if args.suite:
        for (algos, layouts, backends, devs, bal,
             pipe) in _suite_cells(args.suite):
            rep, bok = run_matrix(algos=algos, layouts=layouts,
                                  backends=backends, device_counts=devs,
                                  n=args.n, M=args.workers, balance=bal,
                                  pipeline=pipe)
            ok &= bok
            report["cells"].update(rep["cells"])
        report["all_to_all_in_hlo"] = check_all_to_all(
            n=args.n, M=args.workers, devices=8)
        ok &= report["all_to_all_in_hlo"]
        report["routed_memory"] = check_routed_memory(
            n=args.n, M=args.workers, devices=8)
        ok &= report["routed_memory"]["ok"]
        report["masked_lanes_ok"] = check_masked_lanes(
            devices=(1, 8) if args.suite == "full" else (8,))
        ok &= report["masked_lanes_ok"]
        report["hier_levels"] = check_hier_levels(
            n=args.n, M=args.workers, hier=(2, 4))
        ok &= report["hier_levels"]["ok"]
        report["hier_caps_ok"] = check_hier_caps(M=args.workers,
                                                 hier=(2, 4))
        ok &= report["hier_caps_ok"]
        report["gspmm_hier"] = check_gspmm_hier(n=args.n, M=args.workers,
                                                hier=(2, 4))
        ok &= report["gspmm_hier"]["ok"]
    else:
        for bal in args.balance:
            rep, bok = run_matrix(algos=tuple(args.algos),
                                  layouts=tuple(args.layouts),
                                  device_counts=tuple(args.devices),
                                  n=args.n, M=args.workers, balance=bal,
                                  pipeline=args.pipeline)
            ok &= bok
            report["cells"].update(rep["cells"])
        if not args.skip_hlo_check:
            report["all_to_all_in_hlo"] = check_all_to_all(
                n=args.n, M=args.workers,
                devices=max(args.devices, key=_flat_devices))
            ok &= report["all_to_all_in_hlo"]
    report["ok"] = bool(ok)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    print(f"[shard_check] {'ALL CELLS OK' if ok else 'PARITY VIOLATIONS'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Sharded-vs-single-device parity harness.

Runs every algorithm x layout x backend cell of the conformance matrix
through the sharded executor at each requested device count and compares
against the single-device batched simulation:

* integer / min / max results (hashmin, sssp, sv, msf labels, attribute
  gather) must be **bitwise identical**;
* PageRank (float sum combine) must agree to tight tolerance (the
  exchange changes float reduction order, nothing else);
* every ``msgs_*`` / ``per_worker_*`` statistic must be integer-exact;
* the dense sharded Ch_msg must actually lower to an ``all-to-all``
  collective (checked in the compiled HLO).

Run as a module (it forces the host device count BEFORE importing jax, so
it works on a plain CPU machine and in CI):

    PYTHONPATH=src python -m repro.launch.shard_check --devices 1 8 \
        --out shard-parity.json

Exits non-zero on the first violated cell.  tests/test_conformance.py
drives it in a subprocess (the in-process suite keeps the single-device
invariant); benchmarks/run.py --smoke asserts its verdict too.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch.xla_flags import force_host_devices


ALGOS = ("hashmin", "pagerank", "sssp", "sv", "msf", "attr_bcast")


def run_matrix(algos=ALGOS, layouts=("padded", "csr"),
               backends=("dense", "pallas"), device_counts=(1, 2, 8),
               n=180, M=8, tau=8, seed=0, balance="hash",
               split_factor=1.1):
    """Returns (report dict, ok flag).  Call only after jax sees enough
    devices (``xla_flags.force_host_devices`` before the first import).
    ``balance`` selects the partitioner mode; ``"split"`` requires the csr
    layout, so padded cells are skipped there."""
    import numpy as np
    import jax.numpy as jnp
    from repro.algorithms.attr_bcast import attribute_broadcast
    from repro.algorithms.hashmin import hashmin
    from repro.algorithms.msf import msf
    from repro.algorithms.pagerank import pagerank
    from repro.algorithms.sssp import sssp
    from repro.algorithms.sv import sv
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    if balance == "split":
        layouts = tuple(lay for lay in layouts if lay == "csr")
    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    pgs = {lay: partition(g, M, tau=tau, seed=seed, layout=lay,
                          balance=balance, split_factor=split_factor)
           for lay in layouts}

    def run_algo(algo, pg, backend, devices):
        if algo == "hashmin":
            l, s, nss = hashmin(pg, backend=backend, devices=devices)
            return {"exact": np.asarray(l)}, {}, s, int(nss)
        if algo == "pagerank":
            pr, s, nss = pagerank(pg, n_iters=8, tol=1e-12,
                                  backend=backend, devices=devices)
            return {}, {"pr": np.asarray(pr)}, s, int(nss)
        if algo == "sssp":
            d, s, nss = sssp(pg, int(pg.perm[0]), backend=backend,
                             devices=devices)
            return {"exact": np.asarray(d)}, {}, s, int(nss)
        if algo == "sv":
            l, s, nss = sv(pg, backend=backend, devices=devices)
            return {"exact": np.asarray(l)}, {}, s, int(nss)
        if algo == "msf":
            (lab, tw, ne), s, nss = msf(pg, backend=backend,
                                        devices=devices)
            return ({"exact": np.asarray(lab), "ne": int(ne)},
                    {"tw": float(tw)}, s, int(nss))
        attr = jnp.arange(pg.n_pad, dtype=jnp.float32
                          ).reshape(pg.M, pg.n_loc) * 3
        ea, s = attribute_broadcast(pg, attr, devices=devices)
        return {"exact": np.asarray(ea)}, {}, s, 2

    report = {"n": n, "M": M, "tau": tau, "balance": balance, "cells": {}}
    ok = True
    for algo in algos:
        for lay in layouts:
            for be in backends:
                pg = pgs[lay]
                ref_e, ref_a, ref_s, ref_n = run_algo(algo, pg, be, None)
                for D in device_counts:
                    name = f"{algo}/{lay}/{be}/{balance}/devices={D}"
                    errs = []
                    e, a, s, nss = run_algo(algo, pg, be, D)
                    if nss != ref_n:
                        errs.append(f"supersteps {nss} != {ref_n}")
                    for k in ref_e:
                        if not np.array_equal(np.asarray(e[k]),
                                              np.asarray(ref_e[k])):
                            errs.append(f"result {k!r} not bitwise equal")
                    for k in ref_a:
                        if not np.allclose(a[k], ref_a[k],
                                           rtol=1e-5, atol=1e-7):
                            errs.append(f"result {k!r} out of tolerance")
                    if set(s) != set(ref_s):
                        errs.append("stats keys differ")
                    else:
                        for k in ref_s:
                            if not np.array_equal(np.asarray(s[k]),
                                                  np.asarray(ref_s[k])):
                                errs.append(f"stat {k!r} differs: "
                                            f"{np.asarray(s[k])} vs "
                                            f"{np.asarray(ref_s[k])}")
                    report["cells"][name] = errs
                    ok &= not errs
                    print(f"[shard_check] {name}: "
                          + ("OK" if not errs else "; ".join(errs)))
    return report, ok


def check_all_to_all(n=180, M=8, tau=8, devices=8) -> bool:
    """The dense sharded Ch_msg join must compile to a real all-to-all."""
    from repro.core import exec as exec_mod
    from repro.core.plan import identity_of
    import jax.numpy as jnp
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(n, avg_deg=5, seed=1).symmetrized()
    pg = partition(g, M, tau=tau, seed=0, layout="csr")

    def make_step(gr):
        def step(state, i):
            from repro.core.channels import broadcast
            inbox, stats = broadcast(gr, state, gr.vmask, op="min")
            return jnp.minimum(state, inbox), gr.gany(inbox < state), stats
        return step

    state0 = jnp.where(pg.vmask, pg.local_ids().astype(jnp.int32),
                       identity_of("min", jnp.int32))
    fn, args = exec_mod.build_sharded(pg, make_step, state0, 3,
                                      devices=devices)
    txt = fn.lower(*args).compile().as_text()
    found = "all-to-all" in txt
    print(f"[shard_check] dense join lowers to all-to-all: {found}")
    return found


def main() -> None:
    ap = argparse.ArgumentParser()
    # 1 = degenerate one-device mesh, 2 = several workers per device
    # (m_loc > 1 with real collectives), 8 = one worker per device
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 2, 8])
    ap.add_argument("--algos", nargs="+", default=list(ALGOS))
    ap.add_argument("--n", type=int, default=180)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--balance", nargs="+", default=["hash"],
                    help="partition balance modes to sweep (hash / edges "
                         "/ split; split runs csr cells only)")
    ap.add_argument("--layouts", nargs="+", default=["padded", "csr"])
    ap.add_argument("--skip-hlo-check", action="store_true",
                    help="skip the dense all-to-all HLO assertion (it "
                         "only applies to worker-aligned meshes)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    force_host_devices(max(args.devices), default_platform="cpu")

    report = None
    ok = True
    for bal in args.balance:
        rep, bok = run_matrix(algos=tuple(args.algos),
                              layouts=tuple(args.layouts),
                              device_counts=tuple(args.devices),
                              n=args.n, M=args.workers, balance=bal)
        ok &= bok
        if report is None:
            report = rep
        else:
            report["cells"].update(rep["cells"])
    if not args.skip_hlo_check:
        report["all_to_all_in_hlo"] = check_all_to_all(
            n=args.n, M=args.workers, devices=max(args.devices))
        ok &= report["all_to_all_in_hlo"]
    report["ok"] = bool(ok)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    print(f"[shard_check] {'ALL CELLS OK' if ok else 'PARITY VIOLATIONS'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

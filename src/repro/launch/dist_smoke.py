"""Two-process ``jax.distributed`` localhost smoke for the hierarchical
(host, device) mesh.

Launches itself ``--hosts`` times (default 2) as real OS processes, each
calling ``jax.distributed.initialize`` against a localhost coordinator
with ``--per-host`` forced CPU devices, then runs one sharded hashmin on
the 2-D ``(hosts, per_host)`` mesh and compares against the
single-process reference.  This is the launch path a real multi-host
deployment uses (process h owns mesh row h; ``launch/mesh.py`` maps
worker block ``[h*T, (h+1)*T)`` onto it).

jaxlib's CPU backend cannot *execute* multi-process computations (no
cross-process CPU collective transport in this build: execution fails
with ``Multiprocess computations aren't implemented on the CPU
backend``), so on CPU-only machines the smoke verifies the coordinator
handshake + global device enumeration and then SKIPS the execution leg,
exiting 0.  On a real multi-host accelerator fleet the same entrypoint
runs the full parity check.

    PYTHONPATH=src python -m repro.launch.dist_smoke

Exit codes: 0 = parity OK or graceful CPU-backend skip; 1 = real
failure (handshake broke, wrong device counts, or parity violated).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_CPU_UNSUPPORTED = "Multiprocess computations aren't implemented"


def _worker(rank: int, hosts: int, per_host: int, port: int, n: int,
            M: int) -> int:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={per_host} "
        + os.environ.get("XLA_FLAGS", ""))
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax

    jax.distributed.initialize(f"localhost:{port}", num_processes=hosts,
                               process_id=rank)
    n_local = len(jax.local_devices())
    n_global = len(jax.devices())
    print(f"[dist_smoke] rank {rank}: {n_local} local / {n_global} global "
          f"devices", flush=True)
    if n_local != per_host or n_global != hosts * per_host:
        print(f"[dist_smoke] rank {rank}: device enumeration wrong "
              f"(want {per_host}/{hosts * per_host})", flush=True)
        return 1

    import numpy as np
    from repro.algorithms.hashmin import hashmin
    from repro.graph import generators as gen
    from repro.graph.structs import partition

    g = gen.powerlaw(n, avg_deg=5, seed=1, weighted=True).symmetrized()
    pg = partition(g, M, tau=8, seed=0, layout="csr", hosts=hosts)
    ref, ref_stats, _ = hashmin(pg, backend="pallas")
    try:
        lab, stats, _ = hashmin(pg, backend="pallas",
                                devices=(hosts, per_host))
    except Exception as e:  # noqa: BLE001 — classify, don't mask
        if _CPU_UNSUPPORTED in str(e):
            print(f"[dist_smoke] rank {rank}: SKIP execution — this "
                  f"jaxlib cannot run multi-process computations on the "
                  f"CPU backend (handshake + enumeration verified)",
                  flush=True)
            return 0
        raise
    ok = (np.array_equal(np.asarray(lab), np.asarray(ref))
          and all(np.array_equal(np.asarray(stats[k]),
                                 np.asarray(ref_stats[k]))
                  for k in ref_stats))
    print(f"[dist_smoke] rank {rank}: parity "
          + ("OK" if ok else "VIOLATED"), flush=True)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--per-host", type=int, default=2)
    ap.add_argument("--port", type=int, default=12421)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--timeout", type=int, default=600)
    ap.add_argument("--rank", type=int, default=None,
                    help=argparse.SUPPRESS)  # internal: worker re-exec
    args = ap.parse_args()

    if args.rank is not None:
        sys.exit(_worker(args.rank, args.hosts, args.per_host, args.port,
                         args.n, args.workers))

    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.dist_smoke",
             "--rank", str(r), "--hosts", str(args.hosts),
             "--per-host", str(args.per_host), "--port", str(args.port),
             "--n", str(args.n), "--workers", str(args.workers)],
            env=dict(os.environ))
        for r in range(args.hosts)]
    codes = []
    for p in procs:
        try:
            codes.append(p.wait(timeout=args.timeout))
        except subprocess.TimeoutExpired:
            p.kill()
            codes.append(124)
    print(f"[dist_smoke] worker exit codes: {codes}")
    sys.exit(0 if all(c == 0 for c in codes) else 1)


if __name__ == "__main__":
    main()

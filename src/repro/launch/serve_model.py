"""Model serving driver: batched prefill + greedy decode with KV/SSM
caches.  (Renamed from ``repro.launch.serve``, which is now a deprecated
alias — the graph query service lives in ``repro.launch.serve_graph``.)

    PYTHONPATH=src python -m repro.launch.serve_model --arch mamba2_1_3b \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model_zoo as zoo
from repro.models.transformer import ModelContext


def run(arch: str, reduced: bool, batch: int, prompt_len: int, gen: int,
        seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    ctx = ModelContext(mesh=None, remat="none", q_chunk=max(prompt_len, 64))
    key = jax.random.PRNGKey(seed)
    params = zoo.init_params(cfg, key, 1, jnp.float32)
    rng = np.random.RandomState(seed)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab, (batch, prompt_len)),
                          jnp.int32)
    enc = None
    if cfg.enc_dec:
        enc = jnp.asarray(rng.randn(batch, cfg.enc_seq, cfg.d_model),
                          jnp.float32)

    prefill = jax.jit(lambda p, t, e: zoo.prefill(
        p, cfg, ctx, t, enc_embeds=e, max_len=prompt_len + gen))
    decode = jax.jit(lambda p, t, c: zoo.decode_step(p, cfg, ctx, t, c))

    t0 = time.time()
    logits, cache = prefill(params, prompts, enc)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] {arch}: batch={batch} prompt={prompt_len} gen={gen} "
          f"in {dt:.2f}s ({batch * gen / dt:.1f} tok/s)")
    print("[serve] sample generations (token ids):")
    for b in range(min(batch, 2)):
        print("  ", np.asarray(toks[b][:16]))
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    return toks


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=ARCH_IDS)
    # BooleanOptionalAction so --no-reduced exists: the old
    # action="store_true" + default=True made the flag impossible to
    # turn off from the command line
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    return ap


def main():
    args = build_parser().parse_args()
    run(args.arch, args.reduced, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()

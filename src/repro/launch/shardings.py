"""Sharding rules: parameter PartitionSpecs by path, batch/cache specs by
shape cell.  Rule-based so every assigned architecture (including those whose
head counts don't divide the TP axis) lowers cleanly:

* shard a dim only when it divides the axis size — otherwise replicate that
  tensor (e.g. hymba's 25 heads, gemma3's 8 heads stay replicated on TP=16
  while their MLPs shard; noted in DESIGN.md §Arch-applicability);
* ``long_500k`` (batch=1) shards the KV-cache/sequence axis over every mesh
  axis instead of the batch axis (flash-decode style — softmax stats become
  tiny all-reduces);
* ``zero1=True`` additionally shards optimizer moments/master over the data
  axis (ZeRO-1), the main beyond-paper memory lever.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model_zoo as zoo
from repro.models.transformer import build_stages


def _axsize(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    return math.prod(_axsize(mesh, a) for a in dp_axes(mesh))


def param_spec_for(path_names, shape, cfg: ArchConfig, mp: int) -> P:
    """PartitionSpec for one parameter leaf, by its path and shape."""
    name = path_names[-1]
    div = lambda d: d % mp == 0
    none = P(*([None] * len(shape)))
    if name in ("embed", "out_embed"):
        return P("model", None)
    if name in ("final_norm",):
        return P(None)
    parent = path_names[-2] if len(path_names) >= 2 else ""
    if parent in ("attn", "cross"):
        H, K = cfg.n_heads, cfg.n_kv_heads
        if name == "wq":
            return P(None, None, "model", None) if div(H) else none
        if name in ("wk", "wv"):
            return P(None, None, "model", None) if div(K) else none
        if name == "wo":
            return P(None, "model", None, None) if div(H) else none
    if parent == "mlp":
        if name in ("w_gate", "w_up"):
            return P(None, None, "model") if div(shape[-1]) else none
        if name == "w_down":
            return P(None, "model", None) if div(shape[-2]) else none
    if parent == "moe":
        E = cfg.moe.n_experts
        if name == "router":
            return none
        if name.endswith("_m"):
            return none  # mirrored experts are replicated BY DESIGN (paper)
        return P(None, "model", None, None) if div(E) else none
    if parent == "ssm":
        di, hd = cfg.d_inner, cfg.ssm.head_dim
        ok = div(di) and (di // mp) % hd == 0
        h_ok = ok and div(cfg.n_ssm_heads)
        if name in ("wz", "wx"):
            return P(None, None, "model") if ok else none
        if name == "conv_x":
            return P(None, None, "model") if ok else none
        if name == "out_proj":
            return P(None, "model", None) if ok else none
        if name == "norm":
            return P(None, "model") if ok else none
        if name == "wdt":
            return P(None, None, "model") if h_ok else none
        if name in ("A_log", "D_skip", "dt_bias"):
            return P(None, "model") if h_ok else none
        return none  # wB/wC/conv_B/conv_C (shared across heads)
    return none


def _path_names(path) -> tuple:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(p.key)
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(cfg: ArchConfig, mesh, abstract_tree) -> Any:
    mp = _axsize(mesh, "model")

    def one(path, leaf):
        return param_spec_for(_path_names(path), leaf.shape, cfg, mp)

    return jax.tree_util.tree_map_with_path(one, abstract_tree)


def _zero1_spec(spec: P, shape, mesh) -> P:
    """Extend a param spec with data-axis sharding on the first free,
    divisible dim (ZeRO-1 optimizer-state sharding)."""
    dsz = dp_size(mesh)
    if dsz <= 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (pp, d) in enumerate(zip(parts, shape)):
        if pp is None and d % dsz == 0:
            parts[i] = dp_axes(mesh) if len(dp_axes(mesh)) > 1 else dp_axes(mesh)[0]
            return P(*parts)
    return P(*parts)


def train_state_specs(cfg: ArchConfig, mesh, abstract_state,
                      zero1: bool = False, fsdp: bool = False) -> Dict[str, Any]:
    """zero1: shard optimizer moments/master over the data axis.
    fsdp: additionally shard the parameters themselves over data (GSPMD
    all-gathers them per use — weight-gathered data parallelism).  Required
    for the >=15B archs to fit 16 GB/chip (EXPERIMENTS §Dry-run)."""
    def z1(path, leaf):
        base = param_spec_for(_path_names(path), leaf.shape, cfg,
                              _axsize(mesh, "model"))
        return _zero1_spec(base, leaf.shape, mesh)

    zspecs = jax.tree_util.tree_map_with_path(z1, abstract_state["params"])
    pspecs = (zspecs if fsdp
              else param_specs(cfg, mesh, abstract_state["params"]))
    if zero1 or fsdp:
        ospec = {"master": zspecs, "m": zspecs, "v": zspecs, "step": P()}
    else:
        base = param_specs(cfg, mesh, abstract_state["params"])
        ospec = {"master": base, "m": base, "v": base, "step": P()}
    return {"params": pspecs, "opt": ospec}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Dict[str, P]:
    B = shape.global_batch
    dp = dp_axes(mesh)
    bax = dp if (dp and B % dp_size(mesh) == 0) else None
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(bax, None)}
        if cfg.enc_dec:
            specs["enc_embeds"] = P(bax, None, None)
        return specs
    return {"token": P(bax, None)}


def logits_spec(cfg: ArchConfig, shape: ShapeConfig, mesh) -> P:
    """(B, V_pad) last-token logits: batch on dp, vocab on model."""
    dp = dp_axes(mesh)
    B = shape.global_batch
    bax = dp if (dp and B % dp_size(mesh) == 0) else None
    return P(bax, "model")


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                abstract_cache) -> Any:
    """Spec tree mirroring build_cache structure."""
    B = shape.global_batch
    dp = dp_axes(mesh)
    batch_ok = dp and B % dp_size(mesh) == 0
    bax = dp if batch_ok else None
    mp = _axsize(mesh, "model")
    all_axes = tuple(mesh.axis_names)
    nall = math.prod(mesh.shape.values())

    def seq_ax(clen: int):
        if not batch_ok:
            # long-context single-sequence: shard the cache/seq axis on
            # everything that divides (flash-decode style)
            if clen % nall == 0:
                return all_axes
        return "model" if clen % mp == 0 else None

    def spec_for(path, leaf):
        names = _path_names(path)
        shp = leaf.shape
        name = names[-1]
        if name == "pos":
            return P(bax)
        if name == "enc_out":
            return P(bax, None, None)
        if name in ("k", "v"):   # (L, B, clen, K, hd)
            return P(None, bax, seq_ax(shp[2]), None, None)
        if name == "k_pos":      # (B, clen)
            return P(bax, seq_ax(shp[1]))
        if name == "state":      # (L, B, H, P, N)
            h_ok = cfg.n_ssm_heads % mp == 0
            return P(None, bax, "model" if h_ok else None, None, None)
        if "conv" in names:      # (L, B, w-1, C)
            di_ok = shp[-1] % mp == 0 and shp[-1] == cfg.d_inner
            return P(None, bax, None, "model" if di_ok else None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axis(mesh) -> str:
    return "model"

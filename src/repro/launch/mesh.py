"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def graph_mesh(hosts: int, per_host: int):
    """The 2-D (host, device) worker mesh of the hierarchical graph
    executor: axis ``"h"`` spans hosts, axis ``"w"`` the devices within
    one host, and the flat row-major device order (d = h * per_host + t)
    is the worker-block order, so ``jax.lax.all_to_all`` over ``"w"``
    exchanges within replica groups {h*T..h*T+T-1} (intra-host) and over
    ``"h"`` within column groups {t, T+t, 2T+t, ...} (inter-host) — the
    two collective levels the hierarchical exchanges ride.

    Single-process: force enough host devices before importing jax
    (``XLA_FLAGS=--xla_force_host_platform_device_count=H*T``; the CLIs
    do this) — the mesh then *simulates* the hierarchy, which is what
    the parity/bench suites run.  Multi-process: call
    ``jax.distributed.initialize`` first (one process per host, T local
    devices each) and the same mesh maps ``"h"`` onto real process
    boundaries, because ``jax.make_mesh`` orders global devices
    process-major."""
    hosts, per_host = int(hosts), int(per_host)
    need = hosts * per_host
    if need > len(jax.devices()):
        raise RuntimeError(
            f"graph_mesh({hosts}, {per_host}) needs {need} devices but "
            f"only {len(jax.devices())} are visible")
    return jax.make_mesh((hosts, per_host), ("h", "w"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axis(mesh) -> str:
    return "model"

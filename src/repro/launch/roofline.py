"""Three-term roofline model for TPU v5e, fed by the dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = collective_bytes_per_chip / ICI_BW

All inputs come from the per-device compiled module (cost_analysis + HLO
text), so "per chip" is what the artifacts already contain.  MODEL_FLOPS
(6·N·D for train, 2·N_active per decoded token) gives the useful-compute
ratio that catches remat/dispatch overcompute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (≈ per-chip injection, 1 link)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Paper-standard useful FLOPs for the whole cell (all chips)."""
    pc = cfg.param_counts()
    n_active = pc["active"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_chip: float
    useful_ratio: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (higher = closer to
        the compute roofline with zero overhead)."""
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return ideal / max(self.bound_s, 1e-30)


def analyze(cfg: ArchConfig, shape: ShapeConfig, n_chips: int,
            flops_per_chip: float, bytes_per_chip: float,
            coll_bytes_per_chip: float) -> Roofline:
    mf = model_flops(cfg, shape)
    return Roofline(
        compute_s=flops_per_chip / PEAK_FLOPS,
        memory_s=bytes_per_chip / HBM_BW,
        collective_s=coll_bytes_per_chip / ICI_BW,
        model_flops=mf,
        hlo_flops_per_chip=flops_per_chip,
        useful_ratio=mf / max(flops_per_chip * n_chips, 1e-30),
        n_chips=n_chips,
    )

"""Deprecated alias: the model-zoo serving driver moved to
``repro.launch.serve_model`` (``serve_graph`` is the GRAPH service).
``python -m repro.launch.serve`` keeps working for one release."""
from repro.launch.serve_model import build_parser, main, run  # noqa: F401

if __name__ == "__main__":
    main()

"""LM training driver: checkpointed, restartable, CPU-runnable on reduced
configs and mesh-ready for the full ones.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.transformer import ModelContext
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptConfig
from repro.train.train_step import StepConfig, init_train_state, make_train_step


def run(arch: str, reduced: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str, ckpt_every: int = 50, lr: float = 3e-4,
        seed: int = 0, log_every: int = 10, embed_method: str = "rr"):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    ctx = ModelContext(mesh=None, remat="none", embed_method=embed_method,
                       q_chunk=max(seq, 64))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=seed))
    step_fn = jax.jit(make_train_step(
        cfg, ctx, StepConfig(opt=OptConfig(lr=lr, warmup_steps=20,
                                           total_steps=steps))),
        donate_argnums=(0,))

    def init():
        return init_train_state(cfg, jax.random.PRNGKey(seed), 1, jnp.float32)

    state, start = ckpt.restore_or_init(ckpt_dir, init)
    if start:
        print(f"[train] resumed from step {start}")
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        batch_np = data.batch_at(step)
        if cfg.enc_dec:
            rng = np.random.RandomState(step)
            batch_np["enc_embeds"] = rng.randn(
                batch, cfg.enc_seq, cfg.d_model).astype(np.float32)
        state, metrics = step_fn(state, jax.tree.map(jnp.asarray, batch_np))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    if ckpt_every:
        ckpt.save(ckpt_dir, steps, state)
    return losses


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=ARCH_IDS)
    # BooleanOptionalAction adds --no-reduced (the old store_true +
    # default=True could never be disabled); --full stays as an alias
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--embed-method", default="rr",
                    choices=["gather", "onehot", "rr"])
    return ap


def main():
    args = build_parser().parse_args()
    run(args.arch, args.reduced, args.steps, args.batch, args.seq,
        args.ckpt_dir, args.ckpt_every, args.lr,
        embed_method=args.embed_method)


if __name__ == "__main__":
    main()

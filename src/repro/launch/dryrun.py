import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_1_1b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]

The first two lines above MUST run before any jax import: jax locks the
device count at first init.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch import shardings as sh
from repro.launch.hlo_stats import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops
from repro.models import model_zoo as zoo
from repro.models.transformer import ModelContext
from repro.train.train_step import (StepConfig, abstract_train_state,
                                    make_decode_step, make_prefill_step,
                                    make_train_step)
from jax.sharding import NamedSharding, PartitionSpec as P


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               embed_method: str = "rr", remat: str = "full",
               zero1: bool = False, n_micro: int = 1, q_chunk: int = 1024,
               extra_tag: str = "", scan_layers: bool = False,
               moe_mirror: int = -1, fsdp: bool = False):
    """Lower + compile one cell; returns the artifact dict."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if moe_mirror >= 0 and cfg.is_moe:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, n_mirrored_experts=moe_mirror))
    shape = SHAPES[shape_name]
    ok, why = cfg.shape_supported(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.flatten())
    mp = mesh.shape["model"]
    ctx = ModelContext(mesh=mesh, dp_axes=sh.dp_axes(mesh),
                       embed_method=embed_method, remat=remat,
                       q_chunk=q_chunk, scan_layers=scan_layers)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            state = abstract_train_state(cfg, mp, jnp.bfloat16)
            sspecs = sh.train_state_specs(cfg, mesh, state, zero1=zero1,
                                          fsdp=fsdp)
            bspecs = sh.batch_specs(cfg, shape, mesh)
            inputs = zoo.input_specs(cfg, shape)
            step = make_train_step(cfg, ctx, StepConfig(n_microbatches=n_micro))
            jitted = jax.jit(step,
                             in_shardings=(sh.named(mesh, sspecs),
                                           sh.named(mesh, bspecs)),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, inputs)
        elif shape.kind == "prefill":
            params = zoo.abstract_params(cfg, mp, jnp.bfloat16)
            pspecs = sh.param_specs(cfg, mesh, params)
            bspecs = sh.batch_specs(cfg, shape, mesh)
            inputs = zoo.input_specs(cfg, shape)
            cache = zoo.build_cache(cfg, shape.global_batch, shape.seq_len,
                                    ctx, abstract=True)
            cspecs = sh.cache_specs(cfg, shape, mesh, cache)
            lspec = sh.logits_spec(cfg, shape, mesh)
            fn = make_prefill_step(cfg, ctx, max_len=shape.seq_len)
            jitted = jax.jit(fn,
                             in_shardings=(sh.named(mesh, pspecs),
                                           sh.named(mesh, bspecs)),
                             out_shardings=(NamedSharding(mesh, lspec),
                                            sh.named(mesh, cspecs)))
            lowered = jitted.lower(params, inputs)
        else:  # decode
            params = zoo.abstract_params(cfg, mp, jnp.bfloat16)
            pspecs = sh.param_specs(cfg, mesh, params)
            cache = zoo.build_cache(cfg, shape.global_batch, shape.seq_len,
                                    ctx, abstract=True)
            cspecs = sh.cache_specs(cfg, shape, mesh, cache)
            token = zoo.input_specs(cfg, shape)["token"]
            tspec = sh.batch_specs(cfg, shape, mesh)["token"]
            lspec = sh.logits_spec(cfg, shape, mesh)
            fn = make_decode_step(cfg, ctx)
            jitted = jax.jit(fn,
                             in_shardings=(sh.named(mesh, pspecs),
                                           NamedSharding(mesh, tspec),
                                           sh.named(mesh, cspecs)),
                             out_shardings=(NamedSharding(mesh, lspec),
                                            sh.named(mesh, cspecs)),
                             donate_argnums=(2,))
            lowered = jitted.lower(params, token, cache)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        print(ma)
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, f):
                mem[f] = int(getattr(ma, f))
    except Exception as e:  # CPU backend may not support it
        mem["error"] = str(e)

    cost = compiled.cost_analysis() or {}
    print({k: cost[k] for k in ("flops", "bytes accessed")
           if k in cost})
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rl = analyze(cfg, shape, n_chips, flops, hbm_bytes,
                 coll["total"]["bytes"])
    art = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "options": {"embed_method": embed_method, "remat": remat,
                    "zero1": zero1, "n_micro": n_micro, "q_chunk": q_chunk,
                    "tag": extra_tag},
        "n_chips": n_chips,
        "flops_per_chip": flops,
        "hbm_bytes_per_chip": hbm_bytes,
        "collectives": coll,
        "memory_analysis": mem,
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "model_flops": rl.model_flops, "useful_ratio": rl.useful_ratio,
            "roofline_fraction": rl.roofline_fraction,
        },
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
    }
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS + ["all"])
    ap.add_argument("--shape", required=True, choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--embed-method", default="rr",
                    choices=["gather", "onehot", "rr"])
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="also shard params over data (weight-gathered DP)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--scan-layers", action="store_true",
                    help="scan layer stacks (fast compile, but XLA "
                         "under-counts while-body cost); default unrolled")
    ap.add_argument("--moe-mirror", type=int, default=-1,
                    help="override n_mirrored_experts (paper Thm-2 analog)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            name = f"{arch}.{shape}.{'2x16x16' if args.multi_pod else '16x16'}"
            if args.tag:
                name += f".{args.tag}"
            try:
                art = lower_cell(arch, shape, args.multi_pod,
                                 args.embed_method, args.remat, args.zero1,
                                 args.microbatches, args.q_chunk, args.tag,
                                 scan_layers=args.scan_layers,
                                 moe_mirror=args.moe_mirror, fsdp=args.fsdp)
            except Exception:
                failures += 1
                art = {"arch": arch, "shape": shape, "status": "error",
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "trace": traceback.format_exc()}
                print(f"[FAIL] {name}\n{art['trace']}")
            (outdir / f"{name}.json").write_text(json.dumps(art, indent=1))
            if art["status"] == "ok":
                r = art["roofline"]
                print(f"[OK] {name}: dominant={r['dominant']} "
                      f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                      f"collective={r['collective_s']:.3e}s "
                      f"frac={r['roofline_fraction']:.3f} "
                      f"(compile {art['timing']['compile_s']:.1f}s)")
            elif art["status"] == "skipped":
                print(f"[SKIP] {name}: {art['reason']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

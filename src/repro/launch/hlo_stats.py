"""Parse compiled HLO text for roofline inputs.

``cost_analysis`` has FLOPs and bytes but NOT collective traffic, so we scan
the optimized per-device HLO for every collective op and sum operand sizes
(the bytes each chip injects into the interconnect).

CPU-HLO text does not inline operand types, so we build a symbol table
(name -> bytes) in a first pass and resolve operands in a second.
NOTE (documented XLA limitation): HloCostAnalysis visits while-loop bodies
once, so scanned-layer modules under-count; the dry-run therefore lowers
with unrolled layer stacks (ModelContext.scan_layers=False) when costing.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?)(.*)$")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _type_section_bytes(rest: str) -> int:
    """Bytes of the result type(s) at the start of the RHS (handles tuples)."""
    # type section ends at the op name (first space after the closing
    # bracket/paren run); just grab shapes before the first '(' that is a
    # call — conservative: shapes up to the op-name token.
    m = re.match(r"(\(?[a-z0-9]+\[[0-9,]*\][^=]*?)\s+[a-z][a-z0-9\-]*\(", rest)
    section = m.group(1) if m else rest.split(" ")[0]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(section))


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {bytes, count} from optimized HLO text.
    Async ``-start``/``-done`` pairs are counted once (on -start)."""
    table: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, _, rest = m.groups()
        table[name] = _type_section_bytes(rest)

    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVES}
    for line in lines:
        s = line.strip()
        m = re.search(r"=\s+.*?\s([a-z][a-z\-]*)\(", s)
        if not m:
            continue
        op = m.group(1)
        base = op.replace("-start", "")
        if base not in COLLECTIVES or op.endswith("-done"):
            continue
        call = s[s.index(op + "(") + len(op) + 1:]
        depth, end = 1, len(call)
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = call[:end]
        inline = sum(_shape_bytes(d, sh) for d, sh in _SHAPE_RE.findall(args))
        if inline:
            b = inline
        else:
            b = sum(table.get(nm, 0) for nm in _OPND_RE.findall(args))
        out[base]["bytes"] += b
        out[base]["count"] += 1
    out["total"] = {"bytes": sum(v["bytes"] for v in out.values()),
                    "count": sum(v["count"] for v in out.values())}
    return out

"""Persistent graph-service demo — the acceptance workload.

    PYTHONPATH=src python -m repro.launch.serve_graph \
        --n 200000 --devices 8 --workers 32

Boots a :class:`repro.core.service.GraphService` holding a resident
partitioned + sharded powerlaw graph, then:

1. warms the bucket executors (each traces exactly once);
2. answers a 64-query mixed batch (landmark SSSP + personalized
   PageRank + ego-component lookups) from ONE compiled executor —
   the service's trace counter is asserted flat across the batch;
3. streams a 1%-edge-churn :class:`~repro.graph.structs.EdgeDelta`,
   folded between supersteps by ``fold_delta`` (no re-partition, no
   re-trace — asserted), and
4. checks post-fold answers against a fresh full ``partition()`` of the
   mutated edge list (SSSP + PPR to tolerance, ego exactly).

Args are parsed before jax is imported so ``--devices`` can force host
devices via XLA_FLAGS — keep the repro imports lazy.
"""
from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=64,
                    help="queries per mixed batch")
    ap.add_argument("--buckets", type=int, nargs="+", default=[4, 16, 64],
                    help="query-batch padding buckets (one executor each)")
    ap.add_argument("--churn", type=float, default=0.01,
                    help="fraction of edges removed AND added by the "
                         "streamed mutation")
    ap.add_argument("--ppr-iters", type=int, default=20)
    ap.add_argument("--skip-parity", action="store_true",
                    help="skip the fresh-full-partition cross-check "
                         "(for timing-only runs)")
    return ap


def _mixed_batch(n, size, seed):
    import numpy as np
    from repro.core.service import Query
    rng = np.random.RandomState(seed)
    kinds = (["sssp"] * (size // 3) + ["ppr"] * (size // 3)
             + ["ego"] * (size - 2 * (size // 3)))
    return [Query(k, int(s)) for k, s in zip(kinds, rng.randint(0, n,
                                                                size=size))]


def _churn_delta(g, frac, seed):
    import numpy as np
    from repro.graph.structs import EdgeDelta
    rng = np.random.RandomState(seed + 1)
    half = g.m // 2            # symmetrized: mutate lo<hi halves, mirror
    k = max(int(half * frac), 1)
    ridx = rng.choice(half, size=k, replace=False)
    lo = np.minimum(g.src, g.dst)
    hi = np.maximum(g.src, g.dst)
    key = np.unique(lo.astype(np.int64) * g.n + hi)
    rs, rd = key[ridx] // g.n, key[ridx] % g.n
    a_s = rng.randint(0, g.n, size=k)
    a_d = rng.randint(0, g.n, size=k)
    keep = a_s != a_d
    a_w = rng.rand(int(keep.sum())).astype(np.float32) + 0.01
    return EdgeDelta(add_src=a_s[keep], add_dst=a_d[keep], add_w=a_w,
                     rem_src=rs, rem_dst=rd).symmetrized()


def main():
    args = build_parser().parse_args()
    if args.devices > 1:
        from repro.launch.xla_flags import force_host_devices
        force_host_devices(args.devices)

    import numpy as np
    from repro.api import Engine, EngineConfig
    from repro.core.service import GraphClient, GraphService, Query
    from repro.graph import generators
    from repro.graph.structs import canonical_labels, partition

    g = generators.powerlaw(args.n, avg_deg=args.avg_deg, seed=args.seed,
                            weighted=True).symmetrized()
    cfg = EngineConfig(layout="csr", balance="edges", devices=args.devices)
    t0 = time.time()
    svc = GraphService(g, M=args.workers, config=cfg,
                       buckets=args.buckets, ppr_iters=args.ppr_iters,
                       seed=args.seed)
    client = GraphClient(svc)
    print(f"[serve-graph] resident graph n={g.n} m={g.m} "
          f"M={args.workers} tau={svc.pg.tau} devices={args.devices} "
          f"partitioned in {time.time() - t0:.2f}s")

    t0 = time.time()
    svc.warmup()
    warm_traces = svc.traces
    print(f"[serve-graph] warmup: {warm_traces} traces "
          f"(buckets {svc.buckets} + components) in "
          f"{time.time() - t0:.2f}s")

    # -- 2. the 64-query mixed batch, one executor, zero re-traces -------
    batch = _mixed_batch(g.n, args.batch, args.seed)
    t0 = time.time()
    results = client.request(batch)
    dt = time.time() - t0
    assert svc.traces == warm_traces, (
        f"admission re-traced: {svc.traces - warm_traces}")
    lp = svc.last_pump
    if args.batch <= 3 * max(args.buckets):
        assert lp["slices"] == 1, (
            f"expected one executor run, got {lp['slices']}")
    print(f"[serve-graph] {len(results)} mixed queries "
          f"(sssp={lp['lanes_sssp']} ppr={lp['lanes_ppr']} "
          f"ego={sum(r.query.kind == 'ego' for r in results)}) in "
          f"{dt:.2f}s — {lp['slices']} executor run(s), "
          f"bucket={svc.last_batch['bucket']}, "
          f"{lp['n_supersteps']} supersteps, zero re-traces, "
          f"{len(results) / dt:.1f} q/s")

    # -- 3. streamed 1% churn, folded between supersteps ------------------
    delta = _churn_delta(g, args.churn, args.seed)
    svc.mutate(delta)
    probe = [Query("sssp", 17), Query("ppr", 23), Query("ego", 5)]
    t0 = time.time()
    post = client.request(probe + batch)      # fold + serve in one pump
    dt = time.time() - t0
    assert svc.epoch == 1
    assert all(r.epoch == 1 for r in post), "batch straddled the fold"
    assert svc.traces == warm_traces, (
        f"fold re-traced: {svc.traces - warm_traces}")
    print(f"[serve-graph] folded {len(delta.rem_src):,d} removals + "
          f"{len(delta.add_src):,d} adds and re-answered "
          f"{len(post)} queries in {dt:.2f}s (epoch {svc.epoch}, "
          f"zero re-traces)")

    if args.skip_parity:
        print("[serve-graph] OK (parity skipped)")
        return

    # -- 4. post-fold answers vs a fresh full partition() -----------------
    g2 = svc.snapshot_graph()
    t0 = time.time()
    pg2 = partition(g2, args.workers, tau=svc.pg.tau, seed=args.seed,
                    layout="csr", balance="edges")
    t_full = time.time() - t0
    eng = Engine(cfg)
    rr = eng.run("sssp", pg2, source=int(pg2.perm[17]))
    want = np.asarray(rr.state).reshape(-1)[pg2.perm]
    got = post[0].value
    assert np.allclose(got, want, equal_nan=True), "sssp diverged from " \
        "fresh-partition run after the fold"

    deg = np.bincount(g2.src, minlength=g2.n)
    pr = np.zeros(g2.n)
    pr[23] = 1.0
    restart = pr.copy()
    for _ in range(args.ppr_iters):
        contrib = np.where(deg > 0, pr / np.maximum(deg, 1), 0.0)
        inbox = np.zeros(g2.n)
        np.add.at(inbox, g2.dst, contrib[g2.src])
        pr = svc.ppr_alpha * restart + (1 - svc.ppr_alpha) * inbox
    assert np.allclose(post[1].value, pr, atol=1e-5), "ppr diverged"

    res_cc = eng.run("hashmin", pg2)
    roots = canonical_labels(pg2, res_cc.state)
    sizes = np.bincount(roots, minlength=g2.n)
    assert post[2].value == (int(roots[5]), int(sizes[roots[5]])), \
        "ego diverged"
    print(f"[serve-graph] post-fold parity vs fresh partition() OK "
          f"(full re-partition takes {t_full:.2f}s)")
    print("[serve-graph] OK")


if __name__ == "__main__":
    main()

"""Pre-jax-import environment knobs (keep this module jax-free).

The sharded executor needs D visible devices; on CPU that means
``--xla_force_host_platform_device_count`` must be in XLA_FLAGS *before*
jax initializes.  Every entry point that forces host devices
(graph_run --devices, shard_check, benchmarks/run.py) shares this helper
so the flag mutation can't drift between copies.
"""
from __future__ import annotations

import os


def force_host_devices(n: int, default_platform: str | None = None) -> None:
    """Append the host-device-count flag unless one is already set; must
    run before the first jax import to have any effect."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
    if default_platform:
        os.environ.setdefault("JAX_PLATFORMS", default_platform)

"""Graph-analytics driver — the paper-kind end-to-end workload.

    PYTHONPATH=src python -m repro.launch.graph_run --algo hashmin \
        --graph powerlaw --n 100000 --workers 32 --tau auto

Runs a full BSP computation with the chosen channel configuration and
reports the paper's metrics: total messages under each channel mode,
per-worker balance, supersteps, wall time.

``--devices D`` runs the sharded executor (core/exec.py): the worker axis
is sharded over a D-device mesh and the channel joins lower to real
collectives.  On CPU the driver forces D host devices via XLA_FLAGS, so
args are parsed *before* jax is imported — keep the repro imports lazy.
"""
from __future__ import annotations

import argparse
import time

GRAPH_NAMES = ("powerlaw", "road", "erdos")
ALGOS = ("hashmin", "pagerank", "sv", "sssp", "msf", "attr_bcast", "gcn")


def make_graph(graph: str, n: int, seed: int):
    import numpy as np
    from repro.graph import generators as gen
    if graph == "powerlaw":
        return gen.powerlaw(n, avg_deg=8, seed=seed)
    if graph == "road":
        return gen.grid_road(int(np.sqrt(n)), seed=seed, weighted=True)
    return gen.erdos(n, avg_deg=16, seed=seed)


def build(graph: str, n: int, seed: int, M: int, tau_arg: str,
          layout: str = "padded", balance: str = "hash",
          split_factor: float = 1.2, hosts: int = 0):
    from repro.core.cost_model import choose_tau
    from repro.graph.structs import partition
    g = make_graph(graph, n, seed)
    g = g.symmetrized()
    deg = g.out_degrees()
    if tau_arg == "auto":
        tau = choose_tau(deg, M)
    elif tau_arg == "off":
        tau = None
    else:
        tau = int(tau_arg)
    pg = partition(g, M, tau=tau, seed=seed, layout=layout,
                   balance=balance, split_factor=split_factor,
                   hosts=hosts if hosts > 1 else None)
    return g, pg, tau


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="hashmin", choices=list(ALGOS))
    ap.add_argument("--graph", default="powerlaw", choices=list(GRAPH_NAMES))
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--workers", type=int, default=32)
    ap.add_argument("--tau", default="auto")
    ap.add_argument("--no-mirroring", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="dense", choices=["dense", "pallas"],
                    help="combine-channel implementation: dense vmap "
                         "scatters or the plan-driven segment_combine path")
    ap.add_argument("--layout", default="padded", choices=["padded", "csr"],
                    help="edge representation: padded (M, E_loc) rows "
                         "(reference) or flat csr arrays + row offsets "
                         "(O(E + M + n) host memory)")
    ap.add_argument("--balance", default="hash",
                    choices=["hash", "edges", "edges+refine", "split",
                             "vertex-cut"],
                    help="vertex->worker placement: random hash "
                         "(reference), greedy edge-count-balanced, "
                         "edges + greedy crossness-descent locality "
                         "refinement, edge-balanced + hot-worker "
                         "splitting (csr only), or edges + mega-hub "
                         "vertex-cut (state-row splitting via forced "
                         "mirroring)")
    ap.add_argument("--split-factor", type=float, default=1.2,
                    help="split workers whose edge load exceeds this "
                         "multiple of the mean (balance=split)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the worker axis over this many devices "
                         "(0 = single-device batched simulation); on CPU "
                         "the required host devices are forced via "
                         "XLA_FLAGS")
    ap.add_argument("--hosts", type=int, default=0,
                    help="arrange --devices D as a hierarchical "
                         "(hosts, D/hosts) mesh: the partition becomes "
                         "host-topology-aware, every routed exchange "
                         "combines/dedups per level, and only the "
                         "combined residue crosses the host axis; the "
                         "driver prints intra- vs cross-host "
                         "exchange-volume stats")
    ap.add_argument("--feat-dim", type=int, default=32,
                    help="gcn: embedding feature dimension F — the "
                         "vector-payload width every channel join "
                         "carries as a trailing (lanes, F) block")
    ap.add_argument("--hidden", type=int, default=64,
                    help="gcn: hidden width of the 2-layer GCN")
    ap.add_argument("--classes", type=int, default=8,
                    help="gcn: number of synthetic label classes")
    ap.add_argument("--epochs", type=int, default=10,
                    help="gcn: full-graph AdamW steps")
    ap.add_argument("--pipeline", action="store_true",
                    help="double-buffer the supersteps: chunk every "
                         "routed exchange so chunk k's all_to_all "
                         "overlaps chunk k-1's local combine (results "
                         "keep the parity contract)")
    args = ap.parse_args()

    if args.hosts > 1 and (not args.devices or args.devices % args.hosts):
        ap.error(f"--hosts {args.hosts} needs --devices divisible by it")
    if args.devices > 1:
        from repro.launch.xla_flags import force_host_devices
        force_host_devices(args.devices)

    # jax initializes on first repro import — after the flags above
    import numpy as np
    from repro.api import Engine
    from repro.core.cost_model import straggler_report

    g, pg, tau = build(args.graph, args.n, args.seed, args.workers, args.tau,
                       layout=args.layout, balance=args.balance,
                       split_factor=args.split_factor, hosts=args.hosts)
    if args.hosts > 1 and args.devices:
        dev = (args.hosts, args.devices // args.hosts)
        dev_tag = f"{dev[0]}x{dev[1]}"
    else:
        dev = args.devices if args.devices else None
        dev_tag = str(dev or 1)
    pipe = args.pipeline
    print(f"[graph] {args.graph}: n={g.n} m={g.m} M={args.workers} "
          f"tau={tau} max_deg={int(g.out_degrees().max())} "
          f"backend={args.backend} layout={args.layout} "
          f"balance={args.balance} devices={dev_tag} "
          f"pipeline={'on' if pipe else 'off'}")

    def report_balance(pg_run):
        # printed for the partition the algorithm actually ran (sssp/msf
        # rebuild a weighted partition)
        rep = straggler_report(pg_run.edge_load(phys=True))
        print(f"[balance] {args.balance}: workers {pg_run.M} -> "
              f"{pg_run.M_phys} physical shards; edge-load max/mean="
              f"{rep['max_over_mean']:.2f} cv={rep['cv']:.2f}")
        if dev and args.layout == "csr":
            from repro.core.exec import device_edge_loads
            dl = straggler_report(device_edge_loads(pg_run, dev))
            print(f"[balance] device edge-load max/mean="
                  f"{dl['max_over_mean']:.2f} over {dev_tag} devices")
        from repro.core.exec import crossness_report
        cr = crossness_report(pg_run, dev)
        line = (f"[crossness] cross-worker message fraction="
                f"{cr['cross_worker_frac']:.3f}")
        if "cross_device_frac" in cr:
            line += f" cross-device={cr['cross_device_frac']:.3f}"
        if "cross_host_frac" in cr:
            line += f" cross-host={cr['cross_host_frac']:.3f}"
        print(line)

    mirror = not args.no_mirroring and tau is not None
    be = args.backend
    eng = Engine(backend=be, layout=args.layout, balance=args.balance,
                 split_factor=args.split_factor,
                 hosts=args.hosts if args.hosts > 1 else None,
                 devices=dev, pipeline=pipe, use_mirroring=mirror)

    t0 = time.time()
    if args.algo == "sssp":
        gw = make_graph(args.graph, args.n, args.seed)
        if gw.weight is None:
            gw.weight = np.ones(gw.m, np.float32)
        pg = eng.partition(gw.symmetrized(), args.workers, tau=tau,
                           seed=args.seed)
        res = eng.run("sssp", pg, source=int(pg.perm[0]))
    elif args.algo == "msf":
        gw = make_graph(args.graph, args.n, args.seed)
        if gw.weight is None:
            rng = np.random.RandomState(args.seed)
            gw.weight = rng.rand(gw.m).astype(np.float32) + 0.01
        pg = eng.partition(gw.symmetrized(), args.workers, tau=None,
                           seed=args.seed)
        res = eng.run("msf", pg)
        print(f"[msf] total weight {float(res.state[1]):.2f}, "
              f"{int(res.state[2])} edges")
    elif args.algo == "gcn":
        from repro.core.gspmm import gspmm_sharded
        from repro.train.gcn import normalize_adjacency
        gw = normalize_adjacency(
            make_graph(args.graph, args.n, args.seed).symmetrized())
        pg = eng.partition(gw, args.workers, tau=tau, seed=args.seed)
        res = eng.run("gcn", pg, feat_dim=args.feat_dim,
                      hidden=args.hidden, n_classes=args.classes,
                      epochs=args.epochs, seed=args.seed)
        losses = res.history
        print(f"[gcn] F={args.feat_dim} hidden={args.hidden} "
              f"classes={args.classes}: loss "
              f"{losses[0]:.4f} -> {losses[-1]:.4f} over "
              f"{args.epochs} epochs")
        # message accounting for ONE aggregation join (the training step
        # runs 4 per epoch: 2 forward + 2 backward-cotangent joins)
        _, res.stats = gspmm_sharded(pg, "u_mul_e_sum",
                                     res.state["emb"],
                                     devices=dev or 1, backend=be,
                                     pipeline=pipe, use_mirroring=mirror)
    elif args.algo == "attr_bcast":
        import jax.numpy as jnp
        attr = jnp.arange(pg.n_pad,
                          dtype=jnp.float32).reshape(pg.M, pg.n_loc)
        res = eng.run("attr_bcast", pg, attr=attr)
        res.n_supersteps = 2    # request + respond rounds
    else:
        params = {"n_iters": 30} if args.algo == "pagerank" else {}
        res = eng.run(args.algo, pg, **params)
    stats, n_ss = res.stats, res.n_supersteps
    dt = time.time() - t0

    report_balance(pg)
    print(f"[run] {args.algo}: {int(n_ss)} supersteps in {dt:.2f}s")
    for k in ("msgs_total", "msgs_combined", "msgs_mirror", "msgs_basic",
              "msgs_rr"):
        if k in stats:
            print(f"  {k:16s} {int(stats[k]):>14,d}")
    for k in ("per_worker_total", "per_worker_rr", "per_worker_basic"):
        if k in stats:
            rep = straggler_report(np.asarray(stats[k]))
            print(f"  balance[{k}]: max/mean={rep['max_over_mean']:.2f} "
                  f"cv={rep['cv']:.2f} gini={rep['gini']:.3f}")

    if dev:
        # static wire-lane accounting of the per-superstep exchanges;
        # on a hierarchical mesh cross_host counts only the post-combine
        # residue that actually crosses the host axis
        from repro.core.exec import broadcast_plan_kinds
        from repro.core.exec import exchange_volume_report
        vol = exchange_volume_report(
            pg, dev, plan_kinds=broadcast_plan_kinds(be, mirror))
        print(f"[exchange] devices={dev_tag}: wire lanes/superstep "
              f"total={vol['total']:,d} intra_host={vol['intra_host']:,d} "
              f"cross_host={vol['cross_host']:,d}")
        for name, e in sorted(vol["per_exchange"].items()):
            print(f"  {name:16s} intra={e['intra_host']:>12,d} "
                  f"cross={e['cross_host']:>12,d}")


if __name__ == "__main__":
    main()

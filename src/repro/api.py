"""The one front door: ``Engine`` + ``EngineConfig`` + ``RunResult``.

Every execution knob the engine understands — backend, edge layout,
balance mode, device mesh, pipelining, mirroring — lives in ONE frozen
``EngineConfig`` instead of being re-plumbed as seven keyword arguments
through every ``algorithms/*.py`` signature, every driver, and every
benchmark.  Algorithms expose a canonical

    run(pg, config, **algo_params) -> RunResult

and the legacy positional-tuple entry points (``hashmin(pg, ...)`` ->
``(labels, stats, n)`` etc.) survive for one PR as thin deprecated
wrappers around it.

    from repro.api import Engine, EngineConfig

    eng = Engine(EngineConfig(backend="pallas", layout="csr", devices=8))
    res = eng.run("pagerank", g, M=64, n_iters=30)
    res.state, res.stats, res.n_supersteps, res.history

``Engine.run`` accepts a host ``Graph`` (partitioned on the fly with the
config's layout/balance; pass ``M``/``tau``/``seed``) or an existing
``PartitionedGraph``.  ``graph_run``, ``shard_check``, ``train/gcn`` and
the resident graph service (``core/service.py``) all construct an Engine.
"""
from __future__ import annotations

import dataclasses
import importlib
import warnings
from typing import Any, Optional, Tuple, Union

import numpy as np

from repro.graph import structs

#: algo name -> (module, canonical entry point).  Imports are lazy so
#: ``repro.api`` stays importable from inside the algorithm modules.
ALGORITHMS = {
    "hashmin": "repro.algorithms.hashmin",
    "pagerank": "repro.algorithms.pagerank",
    "sssp": "repro.algorithms.sssp",
    "sv": "repro.algorithms.sv",
    "msf": "repro.algorithms.msf",
    "attr_bcast": "repro.algorithms.attr_bcast",
    "gcn": "repro.train.gcn",
}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution configuration, orthogonal to any one algorithm.

    ``devices``: None = single-device batched simulation; an int D = the
    1-D sharded mesh; a tuple (H, T) = the hierarchical (host, device)
    mesh.  ``hosts`` additionally makes ``partition()`` place workers
    host-affinely (usually set together with devices=(H, T)).
    """
    backend: str = "dense"          # "dense" | "pallas" channel combine
    layout: str = "padded"          # "padded" | "csr" edge layout
    balance: str = "hash"           # one of graph.partitioner.BALANCES
    devices: Union[int, Tuple[int, int], None] = None
    hosts: Optional[int] = None
    pipeline: bool = False          # double-buffer sharded exchanges
    use_mirroring: bool = True      # Ch_mir for >= tau vertices
    split_factor: float = 1.2       # balance="split" hot-worker factor


@dataclasses.dataclass
class RunResult:
    """Uniform algorithm result: no positional-tuple arity to remember.

    ``state`` is the algorithm's output pytree (labels / pr / dist /
    (labels, total_w, n_edges) / edge attrs / trained params);
    ``history`` is the per-superstep trace when recorded, else None.
    """
    state: Any
    stats: dict
    n_supersteps: int
    history: Any = None

    def load_report(self) -> Optional[dict]:
        """Measured per-worker load telemetry of this run: the
        ``cost_model.straggler_report`` of the summed superstep
        ``per_worker_total`` stats (max/mean imbalance + the worker
        ids carrying the tail) — the signal the resident service's
        elastic repartition trigger watches.  None when the run kept
        no per-worker stats."""
        per_worker = self.stats.get("per_worker_total")
        if per_worker is None:
            parts = [np.asarray(self.stats[k], np.int64)
                     for k in ("per_worker_basic", "per_worker_combined",
                               "per_worker_mirror")
                     if k in self.stats]
            if not parts:
                return None
            per_worker = sum(parts)
        from repro.core import cost_model
        pw = np.asarray(per_worker, np.int64)
        rep = cost_model.straggler_report(pw)
        rep["per_worker_total"] = pw
        rep["top_workers"] = np.argsort(-pw)[:4].tolist()
        return rep


def warn_legacy(name: str, replacement: str) -> None:
    """The one DeprecationWarning every legacy tuple entry point emits
    (``repro.api.Engine`` / the canonical ``run()`` never warns)."""
    warnings.warn(
        f"{name} is deprecated; use {replacement} "
        f"(repro.api.Engine front door) instead",
        DeprecationWarning, stacklevel=3)


def config_of(pg: structs.PartitionedGraph, **overrides) -> EngineConfig:
    """An EngineConfig whose partition-time fields mirror ``pg``."""
    base = dict(layout=pg.layout, balance=pg.balance,
                split_factor=pg.split_factor, hosts=pg.hosts)
    base.update(overrides)
    return EngineConfig(**base)


class Engine:
    """Facade binding an EngineConfig to partitioning + algorithm runs."""

    def __init__(self, config: Optional[EngineConfig] = None, **overrides):
        if config is None:
            config = EngineConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config

    def partition(self, g: structs.Graph, M: int,
                  tau: Optional[int] = None, seed: int = 0,
                  perm=None) -> structs.PartitionedGraph:
        cfg = self.config
        return structs.partition(g, M, tau=tau, seed=seed,
                                 layout=cfg.layout, balance=cfg.balance,
                                 split_factor=cfg.split_factor,
                                 hosts=cfg.hosts, perm=perm)

    def run(self, algo: str, graph, M: Optional[int] = None,
            tau: Optional[int] = None, seed: int = 0,
            **algo_params) -> RunResult:
        """Run ``algo`` on ``graph`` (a PartitionedGraph, or a host Graph
        partitioned on the fly — then ``M`` is required)."""
        if algo not in ALGORITHMS:
            raise ValueError(f"unknown algo {algo!r}; one of "
                             f"{sorted(ALGORITHMS)}")
        if isinstance(graph, structs.PartitionedGraph):
            pg = graph
        else:
            if M is None:
                raise ValueError("partitioning a Graph on the fly needs M")
            pg = self.partition(graph, M, tau=tau, seed=seed)
        mod = importlib.import_module(ALGORITHMS[algo])
        return mod.run(pg, self.config, **algo_params)

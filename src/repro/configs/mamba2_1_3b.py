"""Mamba2-1.3B: 48L d_model=2048, attention-free SSD, ssm_state=128.

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2_1_3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4,
                  n_groups=1, chunk=128),
    source="arXiv:2405.21060; unverified",
)

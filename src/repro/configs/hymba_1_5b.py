"""Hymba-1.5B: 32L d_model=1600 25H (GQA kv=5) d_ff=5504, parallel attn+mamba
heads, ssm_state=16.

[arXiv:2411.13676; hf]
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba_1_5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=64, conv_width=4,
                  n_groups=1, chunk=128),
    sliding_window=1024,  # hymba uses local attn in most layers
    global_every=16,
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
)

"""Llama-4-Scout-17B-16E: 48L d_model=5120 40H (GQA kv=8) MoE 16 experts top-1.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4_scout_17b_a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all-MoE FFN
    vocab=202048,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25, n_mirrored_experts=0),
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

"""Gemma-3-4B: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    sliding_window=1024,
    global_every=6,  # every 6th layer is global => 5:1 local:global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)

"""Whisper-medium: enc-dec, 24L(+24L enc) d_model=1024 16H d_ff=4096 vocab=51865.

Conv audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings of shape (batch, enc_seq, d_model).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    enc_dec=True,
    n_enc_layers=24,
    enc_seq=1500,
    frontend_stub=True,
    rope_theta=10_000.0,
    source="arXiv:2212.04356; unverified",
)

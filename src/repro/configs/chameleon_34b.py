"""Chameleon-34B: 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

Early-fusion VLM: VQ image tokens share the text vocab; the VQ tokenizer
frontend is a STUB per the assignment (token ids arrive pre-tokenized).
[arXiv:2405.09818; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon_34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    frontend_stub=True,
    rope_theta=10_000.0,
    source="arXiv:2405.09818; unverified",
)

"""OLMoE-1B-7B: 16L d_model=2048 16H (kv=16) MoE 64 experts top-8 d_ff_e=1024.

[arXiv:2409.02060; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                  capacity_factor=1.25, n_mirrored_experts=0),
    rope_theta=10_000.0,
    source="arXiv:2409.02060; hf",
)

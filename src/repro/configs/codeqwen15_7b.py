"""CodeQwen1.5-7B: 32L d_model=4096 32H (kv=32, MHA) d_ff=13440 vocab=92416.

[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen15_7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)

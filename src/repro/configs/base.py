"""Architecture & shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the model zoo
(``repro.models.model_zoo``) turns a config into init/apply functions and the
launchers select them with ``--arch <id>``.  ``reduced()`` returns a
small-but-same-family config for CPU smoke tests; the full configs are only
ever lowered abstractly (ShapeDtypeStruct) by the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # Paper technique (mirroring, Thm 2 analog): replicate the n hottest
    # experts on every EP rank so their traffic never crosses the network.
    n_mirrored_experts: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # Sliding-window pattern: window size (0 = full attention everywhere);
    # every ``global_every``-th layer (1-indexed) is global.
    sliding_window: int = 0
    global_every: int = 0
    # Encoder-decoder (whisper): n_enc_layers encoder layers over enc_seq
    # precomputed frame embeddings (conv frontend is a stub per assignment).
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0
    # Modality stub: inputs may be precomputed embeddings (audio frames /
    # VQ image-token embeddings) instead of token ids.
    frontend_stub: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    source: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim

    def padded_vocab(self, model_parallel: int) -> int:
        """Vocab padded so the embedding shards evenly on the model axis."""
        return _round_up(self.vocab, max(model_parallel, 128))

    @property
    def supports_long_context(self) -> bool:
        """True iff decode state is sub-quadratic in context (SSM state or
        sliding-window cache) -- gates the ``long_500k`` cell."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def shape_supported(self, shape: ShapeConfig) -> Tuple[bool, str]:
        if shape.name == "long_500k" and not self.supports_long_context:
            return False, (
                "pure full-attention arch: 500k dense KV has no sub-"
                "quadratic mode (documented skip, DESIGN.md §Arch)"
            )
        return True, ""

    # ---- params accounting (roofline MODEL_FLOPS) --------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and active-per-token."""
        D, F, L = self.d_model, self.d_ff, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        dense_mlp = 3 * D * F if F else 0
        per_layer = attn + dense_mlp + 2 * D
        total = 0
        active = 0
        if self.family == "ssm":
            zxbcdt = 2 * self.d_inner + 2 * self.ssm.n_groups * self.ssm.d_state + self.n_ssm_heads
            per_layer = D * zxbcdt + self.d_inner * D + 3 * self.n_ssm_heads + 2 * D
            total = active = L * per_layer
        elif self.is_moe:
            e = self.moe
            expert = 3 * D * e.d_ff_expert
            router = D * e.n_experts
            per_layer = attn + router + 2 * D
            total = L * (per_layer + e.n_experts * expert)
            active = L * (per_layer + e.top_k * expert)
        else:
            if self.is_hybrid:
                zxbcdt = 2 * self.d_inner + 2 * self.ssm.n_groups * self.ssm.d_state + self.n_ssm_heads
                per_layer += D * zxbcdt + self.d_inner * D + 3 * self.n_ssm_heads
            total = active = L * per_layer
            if self.enc_dec:
                # decoder cross-attention + encoder stack
                total += self.n_enc_layers * per_layer + L * (2 * D * K * hd + D * H * hd + H * hd * D)
                active = total
        emb = self.vocab * D * (1 if self.tie_embeddings else 2)
        return {"total": total + emb, "active": active + emb,
                "body_total": total, "body_active": active}

    # ---- smoke-test reduction ----------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            sliding_window=16 if self.sliding_window else 0,
            global_every=self.global_every if self.sliding_window else 0,
            enc_dec=self.enc_dec,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.enc_dec else 0,
            frontend_stub=self.frontend_stub,
            norm_eps=self.norm_eps,
            rope_theta=self.rope_theta,
            source="smoke",
        )
        if self.is_moe:
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2),
                                  d_ff_expert=64,
                                  n_mirrored_experts=self.moe.n_mirrored_experts and 1)
        if self.ssm.d_state:
            kw["ssm"] = SSMConfig(d_state=8, expand=2, head_dim=16, chunk=8)
        return ArchConfig(**kw)


ARCH_IDS = [
    "llama4_scout_17b_a16e",
    "olmoe_1b_7b",
    "gemma3_4b",
    "starcoder2_15b",
    "codeqwen15_7b",
    "tinyllama_1_1b",
    "whisper_medium",
    "mamba2_1_3b",
    "hymba_1_5b",
    "chameleon_34b",
]

# CLI aliases (hyphenated ids from the assignment sheet).
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "mamba2-1.3b": "mamba2_1_3b",
    "hymba-1.5b": "hymba_1_5b",
})


def get_config(arch: str) -> ArchConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}

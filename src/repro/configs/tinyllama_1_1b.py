"""TinyLlama-1.1B: 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.

[arXiv:2401.02385; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama_1_1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab=32000,
    rope_theta=10_000.0,
    source="arXiv:2401.02385; hf",
)

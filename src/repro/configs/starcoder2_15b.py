"""StarCoder2-15B: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.

[arXiv:2402.19173; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    rope_theta=100_000.0,
    source="arXiv:2402.19173; hf",
)

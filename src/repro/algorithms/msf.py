"""Minimum spanning forest (paper §3.5): Boruvka with the SEAS optimization
("storing edges at subvertices") — edges stay distributed at subvertices,
which query their supervertex (request-respond!) every round; supervertices
aggregate min-edge picks through the combined scatter channel.

Per round:
  1. every edge endpoint asks the owner of its neighbor for D[v] (Ch_req);
  2. a 3-stage scatter-min elects each component's min edge under the total
     order (w, min(Du,Dv), max(Du,Dv)) — ties cannot create >2-cycles;
  3. mutual picks form conjoined trees; the smaller root becomes the
     supervertex; pointer jumping (more Ch_req) flattens the forest —
     towards the end a supervertex serves requests from ALL its subvertices,
     the exact bottleneck the paper's request-respond channel removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import gather, gather_edges, scatter_edges
from repro.graph.structs import PartitionedGraph
from repro.algorithms.sv import _acc

IMAX = jnp.iinfo(jnp.int32).max


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        max_rounds: int = 40, jump_iters: int = 20) -> RunResult:
    """Boruvka MSF under an EngineConfig.  ``state`` is the tuple
    (labels, total_weight, n_edges).  Requires pg built from a
    *weighted, symmetrized* graph.

    Edge-shaped reads/writes (per-edge supervertex queries, min-edge
    election) go through the pg-level channel wrappers, which follow
    ``pg.layout`` (padded rows vs flat csr) and, under the sharded
    executor, the device mesh.  State-shaped ops (pointer jumping) are
    layout-independent."""
    cfg = config or EngineConfig()
    del jump_iters  # pointer jumping loops to convergence
    backend = cfg.backend

    def make_step(g):
        M = g.M

        def step(state, i):
            D, total_w, n_edges = state
            ids = g.local_ids().astype(jnp.int32)
            stats: dict = {}

            Dv, s = gather_edges(g, D, g.all_dst, g.all_mask)
            stats = _acc(stats, s, M)
            Du = g.edge_src_values(D, g.all_src)
            cross = g.all_mask & (Dv != Du)

            # --- 3-stage min-edge election per supervertex ---------------
            inf_f = jnp.full(ids.shape, jnp.inf, jnp.float32)
            wmin, s = scatter_edges(g, inf_f, Du, g.all_w, cross, "min",
                                    backend=backend)
            stats = _acc(stats, s, M)
            wmin_e, s = gather_edges(g, wmin, Du, cross)
            stats = _acc(stats, s, M)
            sel = cross & (g.all_w == wmin_e)

            lo = jnp.minimum(Du, Dv)
            hi = jnp.maximum(Du, Dv)
            imax_i = jnp.full(ids.shape, IMAX, jnp.int32)
            lomin, s = scatter_edges(g, imax_i, Du, lo, sel, "min",
                                     backend=backend)
            stats = _acc(stats, s, M)
            lomin_e, s = gather_edges(g, lomin, Du, sel)
            stats = _acc(stats, s, M)
            sel &= lo == lomin_e

            himin, s = scatter_edges(g, imax_i, Du, hi, sel, "min",
                                     backend=backend)
            stats = _acc(stats, s, M)
            himin_e, s = gather_edges(g, himin, Du, sel)
            stats = _acc(stats, s, M)
            sel &= hi == himin_e

            other = jnp.where(lo == Du, hi, lo)
            tgt, s = scatter_edges(g, imax_i, Du, other, sel, "min",
                                   backend=backend)
            stats = _acc(stats, s, M)

            valid = g.vmask & (tgt != IMAX)
            t_of_t, s = gather(g, tgt, jnp.where(valid, tgt, 0), valid)
            stats = _acc(stats, s, M)
            mutual = valid & (t_of_t == ids)

            add = valid & (~mutual | (ids < tgt))
            total_w = total_w + g.gsum(jnp.where(add, wmin, 0.0))
            n_edges = n_edges + g.gsum(add)

            is_root = D == ids
            hookD = jnp.where(mutual & (ids < tgt), ids, tgt)
            D1 = jnp.where(is_root & valid, hookD, D)

            # --- pointer jumping (subvertices chase the supervertex) -----
            def jcond(c):
                _, changed, _ = c
                return changed

            def jbody(c):
                Dj, _, cnt = c
                DD, s = gather(g, Dj, Dj, g.vmask)
                cnt = (cnt[0] + s["msgs_rr"], cnt[1] + s["msgs_basic"],
                       cnt[2] + s["per_worker_rr"],
                       cnt[3] + s["per_worker_basic"])
                return DD, g.gany(DD != Dj), cnt

            zero = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                    jnp.zeros((M,), jnp.int32), jnp.zeros((M,), jnp.int32))
            D2, _, cnt = lax.while_loop(jcond, jbody,
                                        (D1, g.gany(D1 != D), zero))
            stats = _acc(stats, {"msgs_rr": cnt[0], "msgs_basic": cnt[1],
                                 "per_worker_rr": cnt[2],
                                 "per_worker_basic": cnt[3]}, M)

            halted = ~g.gany(valid)
            return (D2, total_w, n_edges), halted, stats
        return step

    state0 = (pg.local_ids().astype(jnp.int32), jnp.zeros((), jnp.float32),
              jnp.zeros((), jnp.int32))
    if cfg.devices is None:
        st, stats, n, _ = bsp.run(jax.jit(make_step(pg)), state0,
                                  max_rounds, pipeline=cfg.pipeline)
    else:
        st, stats, n, _ = exec_mod.run_sharded(pg, make_step, state0,
                                               max_rounds,
                                               devices=cfg.devices,
                                               pipeline=cfg.pipeline)
    return RunResult(state=st, stats=stats, n_supersteps=n)


def msf(pg: PartitionedGraph, max_rounds: int = 40, jump_iters: int = 20,
        backend: str = "dense", devices: int | None = None,
        pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns ((labels,
    total_weight, n_edges), stats, rounds).  Use ``Engine.run("msf",
    ...)``."""
    warn_legacy("msf()", 'Engine.run("msf", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline),
              max_rounds=max_rounds, jump_iters=jump_iters)
    return res.state, res.stats, res.n_supersteps

"""Minimum spanning forest (paper §3.5): Boruvka with the SEAS optimization
("storing edges at subvertices") — edges stay distributed at subvertices,
which query their supervertex (request-respond!) every round; supervertices
aggregate min-edge picks through the combined scatter channel.

Per round:
  1. every edge endpoint asks the owner of its neighbor for D[v] (Ch_req);
  2. a 3-stage scatter-min elects each component's min edge under the total
     order (w, min(Du,Dv), max(Du,Dv)) — ties cannot create >2-cycles;
  3. mutual picks form conjoined trees; the smaller root becomes the
     supervertex; pointer jumping (more Ch_req) flattens the forest —
     towards the end a supervertex serves requests from ALL its subvertices,
     the exact bottleneck the paper's request-respond channel removes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bsp
from repro.core.channels import (rr_gather, rr_gather_flat, scatter_combine,
                                 scatter_combine_flat)
from repro.graph.structs import PartitionedGraph
from repro.algorithms.sv import _acc

IMAX = jnp.iinfo(jnp.int32).max


def msf(pg: PartitionedGraph, max_rounds: int = 40, jump_iters: int = 20,
        backend: str = "dense"):
    """Returns ((total_weight, n_edges, labels), stats, rounds).
    Requires pg built from a *weighted, symmetrized* graph.

    Edge-shaped reads/writes (per-edge supervertex queries, min-edge
    election) follow ``pg.layout``: padded (M, A_loc) rows through
    rr_gather/scatter_combine, flat csr (E,) arrays through the _flat
    twins.  State-shaped ops (pointer jumping) are layout-independent."""
    ids = pg.local_ids().astype(jnp.int32)
    M, n_loc = pg.M, pg.n_loc
    widx = jnp.arange(M)[:, None]
    csr = pg.layout == "csr"
    e_worker = pg.all_src // n_loc if csr else None

    def edge_vals(D):
        """D at each edge's (local) source endpoint."""
        if csr:
            return D.reshape(-1)[pg.all_src]
        return D[widx, pg.all_src]

    def edge_read(arr, tgt, msk):
        """rr-read arr[tgt] for edge-shaped global targets."""
        if csr:
            return rr_gather_flat(arr, tgt, e_worker, msk, M, n_loc)
        return rr_gather(arr, tgt, msk, M, n_loc)

    def edge_scatter(base, tgt, upd, msk, op):
        """combined scatter for edge-shaped updates."""
        if csr:
            return scatter_combine_flat(base, tgt, upd, msk, e_worker, op,
                                        M, n_loc, backend=backend)
        return scatter_combine(base, tgt, upd, msk, op, M, n_loc,
                               backend=backend)

    def step(state, i):
        D, total_w, n_edges = state
        stats: dict = {}

        Dv, s = edge_read(D, pg.all_dst, pg.all_mask)
        stats = _acc(stats, s, M)
        Du = edge_vals(D)
        cross = pg.all_mask & (Dv != Du)

        # --- 3-stage min-edge election per supervertex -------------------
        inf_f = jnp.full((M, n_loc), jnp.inf, jnp.float32)
        wmin, s = edge_scatter(inf_f, Du, pg.all_w, cross, "min")
        stats = _acc(stats, s, M)
        wmin_e, s = edge_read(wmin, Du, cross)
        stats = _acc(stats, s, M)
        sel = cross & (pg.all_w == wmin_e)

        lo = jnp.minimum(Du, Dv)
        hi = jnp.maximum(Du, Dv)
        imax_i = jnp.full((M, n_loc), IMAX, jnp.int32)
        lomin, s = edge_scatter(imax_i, Du, lo, sel, "min")
        stats = _acc(stats, s, M)
        lomin_e, s = edge_read(lomin, Du, sel)
        stats = _acc(stats, s, M)
        sel &= lo == lomin_e

        himin, s = edge_scatter(imax_i, Du, hi, sel, "min")
        stats = _acc(stats, s, M)
        himin_e, s = edge_read(himin, Du, sel)
        stats = _acc(stats, s, M)
        sel &= hi == himin_e

        other = jnp.where(lo == Du, hi, lo)
        tgt, s = edge_scatter(imax_i, Du, other, sel, "min")
        stats = _acc(stats, s, M)

        valid = pg.vmask & (tgt != IMAX)
        t_of_t, s = rr_gather(tgt, jnp.where(valid, tgt, 0), valid, M, n_loc)
        stats = _acc(stats, s, M)
        mutual = valid & (t_of_t == ids)

        add = valid & (~mutual | (ids < tgt))
        total_w = total_w + jnp.where(add, wmin, 0.0).sum()
        n_edges = n_edges + add.sum()

        is_root = D == ids
        hookD = jnp.where(mutual & (ids < tgt), ids, tgt)
        D1 = jnp.where(is_root & valid, hookD, D)

        # --- pointer jumping (subvertices chase the new supervertex) -----
        def jcond(c):
            _, changed, _ = c
            return changed

        def jbody(c):
            Dj, _, cnt = c
            DD, s = rr_gather(Dj, Dj, pg.vmask, M, n_loc)
            cnt = (cnt[0] + s["msgs_rr"], cnt[1] + s["msgs_basic"],
                   cnt[2] + s["per_worker_rr"], cnt[3] + s["per_worker_basic"])
            return DD, jnp.any(DD != Dj), cnt

        zero = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((M,), jnp.int32), jnp.zeros((M,), jnp.int32))
        D2, _, cnt = lax.while_loop(jcond, jbody,
                                    (D1, jnp.any(D1 != D), zero))
        stats = _acc(stats, {"msgs_rr": cnt[0], "msgs_basic": cnt[1],
                             "per_worker_rr": cnt[2],
                             "per_worker_basic": cnt[3]}, M)

        halted = ~jnp.any(valid)
        return (D2, total_w, n_edges), halted, stats

    state0 = (ids, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    return bsp.run(jax.jit(step), state0, max_rounds)

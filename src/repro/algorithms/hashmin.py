"""Hash-Min connected components (paper §3.3): broadcast the smallest id
seen so far with a min combiner.  The Fig. 1 balance workload.

The min-combine runs in the *integer* id dtype end to end: the identity is
the int32 sentinel from ``plan.identity_of`` (iinfo.max), never a float
cast.  Casting ids to float32 silently merges distinct components once ids
exceed 2^24 (not representable), exactly the multi-million-vertex regime
the paper targets — pinned by tests/test_large_ids.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.core.plan import identity_of
from repro.graph.structs import PartitionedGraph


def hashmin(pg: PartitionedGraph, max_supersteps: int = 10_000,
            use_mirroring: bool = True, record_history: bool = False,
            backend: str = "dense", devices: int | None = None,
            pipeline: bool = False):
    """Returns (labels, stats, n_supersteps[, history]).  ``devices=None``
    runs the single-device batched simulation; an int runs the sharded
    executor over that many devices (bitwise-identical labels & stats).
    ``pipeline=True`` double-buffers the sharded exchanges (still
    bitwise — min combine)."""
    imax = identity_of("min", jnp.int32)

    def make_step(g):
        def step(state, i):
            minv, active = state
            inbox, stats = broadcast(g, minv, active, op="min",
                                     use_mirroring=use_mirroring,
                                     backend=backend)
            upd = g.vmask & (inbox < minv)
            new = jnp.where(upd, inbox, minv)
            halted = ~g.gany(upd)
            return (new, upd), halted, stats
        return step

    ids = pg.local_ids().astype(jnp.int32)
    minv0 = jnp.where(pg.vmask, ids, imax)
    state0 = (minv0, pg.vmask)
    if devices is None:
        st, stats, n, hist = bsp.run(jax.jit(make_step(pg)), state0,
                                     max_supersteps,
                                     record_history=record_history,
                                     pipeline=pipeline)
    else:
        st, stats, n, hist = exec_mod.run_sharded(
            pg, make_step, state0, max_supersteps,
            record_history=record_history, devices=devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(backend,
                                                     use_mirroring),
            pipeline=pipeline)
    minv = st[0]
    if record_history:
        return minv, stats, n, hist
    return minv, stats, n

"""Hash-Min connected components (paper §3.3): broadcast the smallest id
seen so far with a min combiner.  The Fig. 1 balance workload."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def hashmin(pg: PartitionedGraph, max_supersteps: int = 10_000,
            use_mirroring: bool = True, record_history: bool = False,
            backend: str = "dense"):
    ids = pg.local_ids()

    def step(state, i):
        minv, active = state
        inbox, stats = broadcast(pg, minv.astype(jnp.float32), active,
                                 op="min", use_mirroring=use_mirroring,
                                 backend=backend)
        inbox = jnp.where(jnp.isfinite(inbox), inbox,
                          jnp.inf).astype(jnp.float32)
        upd = pg.vmask & (inbox < minv)
        new = jnp.where(upd, inbox, minv)
        halted = ~jnp.any(upd)
        return (new, upd), halted, stats

    minv0 = jnp.where(pg.vmask, ids.astype(jnp.float32), jnp.inf)
    state0 = (minv0, pg.vmask)
    (minv, _), stats, n = (out := bsp.run(jax.jit(step), state0,
                                          max_supersteps,
                                          record_history=record_history))[:3]
    if record_history:
        return minv.astype(jnp.int32), stats, n, out[3]
    return minv.astype(jnp.int32), stats, n

"""Hash-Min connected components (paper §3.3): broadcast the smallest id
seen so far with a min combiner.  The Fig. 1 balance workload.

The min-combine runs in the *integer* id dtype end to end: the identity is
the int32 sentinel from ``plan.identity_of`` (iinfo.max), never a float
cast.  Casting ids to float32 silently merges distinct components once ids
exceed 2^24 (not representable), exactly the multi-million-vertex regime
the paper targets — pinned by tests/test_large_ids.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.core.plan import identity_of
from repro.graph.structs import PartitionedGraph


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        max_supersteps: int = 10_000,
        record_history: bool = False) -> RunResult:
    """Hash-Min under an EngineConfig.  ``state`` is the (M, n_loc) int32
    label array (min relabeled id of each component).  ``devices=None``
    runs the single-device batched simulation; an int/tuple runs the
    sharded executor (bitwise-identical labels & stats); ``pipeline``
    double-buffers the sharded exchanges (still bitwise — min combine)."""
    cfg = config or EngineConfig()
    imax = identity_of("min", jnp.int32)

    def make_step(g):
        def step(state, i):
            minv, active = state
            inbox, stats = broadcast(g, minv, active, op="min",
                                     use_mirroring=cfg.use_mirroring,
                                     backend=cfg.backend)
            upd = g.vmask & (inbox < minv)
            new = jnp.where(upd, inbox, minv)
            halted = ~g.gany(upd)
            return (new, upd), halted, stats
        return step

    ids = pg.local_ids().astype(jnp.int32)
    minv0 = jnp.where(pg.vmask, ids, imax)
    state0 = (minv0, pg.vmask)
    if cfg.devices is None:
        st, stats, n, hist = bsp.run(jax.jit(make_step(pg)), state0,
                                     max_supersteps,
                                     record_history=record_history,
                                     pipeline=cfg.pipeline)
    else:
        st, stats, n, hist = exec_mod.run_sharded(
            pg, make_step, state0, max_supersteps,
            record_history=record_history, devices=cfg.devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(cfg.backend,
                                                     cfg.use_mirroring),
            pipeline=cfg.pipeline)
    return RunResult(state=st[0], stats=stats, n_supersteps=n,
                     history=hist if record_history else None)


def hashmin(pg: PartitionedGraph, max_supersteps: int = 10_000,
            use_mirroring: bool = True, record_history: bool = False,
            backend: str = "dense", devices: int | None = None,
            pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns (labels, stats,
    n_supersteps[, history]).  Use ``Engine.run("hashmin", ...)`` /
    ``run(pg, EngineConfig(...))``."""
    warn_legacy("hashmin()", 'Engine.run("hashmin", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline,
                               use_mirroring=use_mirroring),
              max_supersteps=max_supersteps, record_history=record_history)
    if record_history:
        return res.state, res.stats, res.n_supersteps, res.history
    return res.state, res.stats, res.n_supersteps

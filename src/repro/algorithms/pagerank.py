"""PageRank (paper §3.2): broadcast pr/deg with a sum combiner; the
mirroring-vs-combining benchmark workload (Fig. 12)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        n_iters: int = 30, damping: float = 0.85, tol: float = 1e-4,
        record_history: bool = False) -> RunResult:
    """PageRank under an EngineConfig.  ``state`` is the (M, n_loc)
    float32 rank vector.  ``pipeline`` double-buffers the sharded
    exchanges (sum combine: values agree to the usual float exchange-
    order round-off; stats stay exact)."""
    cfg = config or EngineConfig()
    n = pg.n

    def make_step(g):
        deg = jnp.maximum(g.deg, 1)

        def step(state, i):
            pr = state
            contrib = jnp.where(g.vmask, pr / deg, 0.0)
            active = g.vmask & (g.deg > 0)
            inbox, stats = broadcast(g, contrib, active, op="sum",
                                     use_mirroring=cfg.use_mirroring,
                                     backend=cfg.backend)
            new_pr = jnp.where(g.vmask,
                               (1 - damping) / n + damping * inbox, 0.0)
            delta = g.gmax(jnp.abs(new_pr - pr).max())
            halted = delta < tol
            return new_pr, halted, stats
        return step

    pr0 = jnp.where(pg.vmask, 1.0 / n, 0.0)
    if cfg.devices is None:
        st, stats, nss, hist = bsp.run(jax.jit(make_step(pg)), pr0, n_iters,
                                       record_history=record_history,
                                       pipeline=cfg.pipeline)
    else:
        st, stats, nss, hist = exec_mod.run_sharded(
            pg, make_step, pr0, n_iters, record_history=record_history,
            devices=cfg.devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(cfg.backend,
                                                     cfg.use_mirroring),
            pipeline=cfg.pipeline)
    return RunResult(state=st, stats=stats, n_supersteps=nss,
                     history=hist if record_history else None)


def pagerank(pg: PartitionedGraph, n_iters: int = 30, damping: float = 0.85,
             tol: float = 1e-4, use_mirroring: bool = True,
             record_history: bool = False, backend: str = "dense",
             devices: int | None = None, pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns (pr, stats,
    n_supersteps[, history]).  Use ``Engine.run("pagerank", ...)``."""
    warn_legacy("pagerank()", 'Engine.run("pagerank", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline,
                               use_mirroring=use_mirroring),
              n_iters=n_iters, damping=damping, tol=tol,
              record_history=record_history)
    if record_history:
        return res.state, res.stats, res.n_supersteps, res.history
    return res.state, res.stats, res.n_supersteps

"""PageRank (paper §3.2): broadcast pr/deg with a sum combiner; the
mirroring-vs-combining benchmark workload (Fig. 12)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def pagerank(pg: PartitionedGraph, n_iters: int = 30, damping: float = 0.85,
             tol: float = 1e-4, use_mirroring: bool = True,
             record_history: bool = False, backend: str = "dense"):
    n = pg.n
    deg = jnp.maximum(pg.deg, 1)

    def step(state, i):
        pr = state
        contrib = jnp.where(pg.vmask, pr / deg, 0.0)
        active = pg.vmask & (pg.deg > 0)
        inbox, stats = broadcast(pg, contrib, active, op="sum",
                                 use_mirroring=use_mirroring,
                                 backend=backend)
        new_pr = jnp.where(pg.vmask, (1 - damping) / n + damping * inbox, 0.0)
        delta = jnp.abs(new_pr - pr).max()
        halted = delta < tol
        return new_pr, halted, stats

    pr0 = jnp.where(pg.vmask, 1.0 / n, 0.0)
    return bsp.run(jax.jit(step, static_argnums=()), pr0, n_iters,
                   record_history=record_history)

"""PageRank (paper §3.2): broadcast pr/deg with a sum combiner; the
mirroring-vs-combining benchmark workload (Fig. 12)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def pagerank(pg: PartitionedGraph, n_iters: int = 30, damping: float = 0.85,
             tol: float = 1e-4, use_mirroring: bool = True,
             record_history: bool = False, backend: str = "dense",
             devices: int | None = None, pipeline: bool = False):
    """Returns (pr, stats, n_supersteps[, history]).  ``pipeline=True``
    double-buffers the sharded exchanges (sum combine: values agree to
    the usual float exchange-order round-off; stats stay exact)."""
    n = pg.n

    def make_step(g):
        deg = jnp.maximum(g.deg, 1)

        def step(state, i):
            pr = state
            contrib = jnp.where(g.vmask, pr / deg, 0.0)
            active = g.vmask & (g.deg > 0)
            inbox, stats = broadcast(g, contrib, active, op="sum",
                                     use_mirroring=use_mirroring,
                                     backend=backend)
            new_pr = jnp.where(g.vmask,
                               (1 - damping) / n + damping * inbox, 0.0)
            delta = g.gmax(jnp.abs(new_pr - pr).max())
            halted = delta < tol
            return new_pr, halted, stats
        return step

    pr0 = jnp.where(pg.vmask, 1.0 / n, 0.0)
    if devices is None:
        st, stats, nss, hist = bsp.run(jax.jit(make_step(pg)), pr0, n_iters,
                                       record_history=record_history,
                                       pipeline=pipeline)
    else:
        st, stats, nss, hist = exec_mod.run_sharded(
            pg, make_step, pr0, n_iters, record_history=record_history,
            devices=devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(backend,
                                                     use_mirroring),
            pipeline=pipeline)
    if record_history:
        return st, stats, nss, hist
    return st, stats, nss

"""Single-source shortest paths (paper §5, "Handling Edge Fields"): the
message value depends on the edge, so Ch_mir applies relay(msg) — the edge
weight is added at the *mirror* side, Ch_msg at the sender side."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        source: int, max_supersteps: int = 10_000) -> RunResult:
    """SSSP under an EngineConfig.  ``source`` is a vertex id in the
    *relabeled* space (use pg.perm[orig]); ``state`` is the (M, n_loc)
    float32 distance array."""
    cfg = config or EngineConfig()

    def make_step(g):
        def step(state, i):
            dist, active = state
            inbox, stats = broadcast(g, dist, active, op="min",
                                     relay="add_w",
                                     use_mirroring=cfg.use_mirroring,
                                     backend=cfg.backend)
            upd = g.vmask & (inbox < dist)
            new = jnp.where(upd, inbox, dist)
            return (new, upd), ~g.gany(upd), stats
        return step

    ids = pg.local_ids()
    dist0 = jnp.where(ids == source, 0.0, jnp.inf)
    dist0 = jnp.where(pg.vmask, dist0, jnp.inf)
    state0 = (dist0, ids == source)
    if cfg.devices is None:
        st, stats, n, _ = bsp.run(jax.jit(make_step(pg)), state0,
                                  max_supersteps, pipeline=cfg.pipeline)
    else:
        st, stats, n, _ = exec_mod.run_sharded(
            pg, make_step, state0, max_supersteps, devices=cfg.devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(cfg.backend,
                                                     cfg.use_mirroring),
            pipeline=cfg.pipeline)
    return RunResult(state=st[0], stats=stats, n_supersteps=n)


def sssp(pg: PartitionedGraph, source: int, max_supersteps: int = 10_000,
         use_mirroring: bool = True, backend: str = "dense",
         devices: int | None = None, pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns (dist, stats, n).
    Use ``Engine.run("sssp", ...)``."""
    warn_legacy("sssp()", 'Engine.run("sssp", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline,
                               use_mirroring=use_mirroring),
              source=source, max_supersteps=max_supersteps)
    return res.state, res.stats, res.n_supersteps

"""Single-source shortest paths (paper §5, "Handling Edge Fields"): the
message value depends on the edge, so Ch_mir applies relay(msg) — the edge
weight is added at the *mirror* side, Ch_msg at the sender side."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def sssp(pg: PartitionedGraph, source: int, max_supersteps: int = 10_000,
         use_mirroring: bool = True, backend: str = "dense"):
    """source: vertex id in the *relabeled* space (use pg.perm[orig])."""
    ids = pg.local_ids()

    def step(state, i):
        dist, active = state
        inbox, stats = broadcast(pg, dist, active, op="min", relay="add_w",
                                 use_mirroring=use_mirroring,
                                 backend=backend)
        upd = pg.vmask & (inbox < dist)
        new = jnp.where(upd, inbox, dist)
        return (new, upd), ~jnp.any(upd), stats

    dist0 = jnp.where(ids == source, 0.0, jnp.inf)
    dist0 = jnp.where(pg.vmask, dist0, jnp.inf)
    (dist, _), stats, n = bsp.run(jax.jit(step), (dist0, ids == source),
                                  max_supersteps)
    return dist, stats, n

"""Single-source shortest paths (paper §5, "Handling Edge Fields"): the
message value depends on the edge, so Ch_mir applies relay(msg) — the edge
weight is added at the *mirror* side, Ch_msg at the sender side."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.graph.structs import PartitionedGraph


def sssp(pg: PartitionedGraph, source: int, max_supersteps: int = 10_000,
         use_mirroring: bool = True, backend: str = "dense",
         devices: int | None = None, pipeline: bool = False):
    """source: vertex id in the *relabeled* space (use pg.perm[orig])."""

    def make_step(g):
        def step(state, i):
            dist, active = state
            inbox, stats = broadcast(g, dist, active, op="min",
                                     relay="add_w",
                                     use_mirroring=use_mirroring,
                                     backend=backend)
            upd = g.vmask & (inbox < dist)
            new = jnp.where(upd, inbox, dist)
            return (new, upd), ~g.gany(upd), stats
        return step

    ids = pg.local_ids()
    dist0 = jnp.where(ids == source, 0.0, jnp.inf)
    dist0 = jnp.where(pg.vmask, dist0, jnp.inf)
    state0 = (dist0, ids == source)
    if devices is None:
        st, stats, n, _ = bsp.run(jax.jit(make_step(pg)), state0,
                                  max_supersteps, pipeline=pipeline)
    else:
        st, stats, n, _ = exec_mod.run_sharded(
            pg, make_step, state0, max_supersteps, devices=devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(backend,
                                                     use_mirroring),
            pipeline=pipeline)
    return st[0], stats, n

"""Attribute broadcast (paper §3.1): annotate every adjacency-list entry
(u in Γout(v)) with a(u).  The pure request-respond microbenchmark of
Fig. 13: per edge, v requests a(u) from u's owner; Ch_req dedups the
requests per (worker, target)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import exec as exec_mod
from repro.core.channels import gather_edges
from repro.graph.structs import PartitionedGraph


def attribute_broadcast(pg: PartitionedGraph, attr,
                        backend: str = "dense",
                        devices: int | None = None,
                        pipeline: bool = False):
    """attr: (M, n_loc) vertex attribute.  Returns (edge_attr aligned with
    pg.all_dst — (M, A_loc) padded layout, (E,) csr layout — and stats).
    stats['msgs_basic'] is the 3-superstep Pregel cost (request+response
    per edge, 2|E| messages); stats['msgs_rr'] the deduplicated Ch_req
    cost, identical across layouts and device counts.

    ``backend`` is accepted for driver uniformity: Ch_req is a pure
    gather with no combine stage, so both backends share one path."""
    del backend

    def make_fn(g):
        def fn(a):
            return gather_edges(g, a, g.all_dst, g.all_mask)
        return fn

    if devices is None:
        out, stats = jax.jit(make_fn(pg))(attr)
        return out, stats

    out, stats = exec_mod.apply_sharded(pg, make_fn, (attr,),
                                        devices=devices, pipeline=pipeline)
    if pg.layout == "csr":
        # sharded csr outputs come back device-concatenated with per-device
        # padding: strip back to the flat (E,) edge order (split partitions
        # place the device boundaries between physical shards)
        D, _ = exec_mod._normalize_devices(devices)
        bounds = exec_mod.device_edge_bounds(pg, devices)["all"]
        counts = np.diff(bounds)
        cap = out.shape[0] // D
        out = jax.numpy.concatenate(
            [out[d * cap:d * cap + int(counts[d])]
             for d in range(D)])
    return out, stats

"""Attribute broadcast (paper §3.1): annotate every adjacency-list entry
(u in Γout(v)) with a(u).  The pure request-respond microbenchmark of
Fig. 13: per edge, v requests a(u) from u's owner; Ch_req dedups the
requests per (worker, target)."""
from __future__ import annotations

import jax
import numpy as np

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import exec as exec_mod
from repro.core.channels import gather_edges
from repro.graph.structs import PartitionedGraph


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        attr) -> RunResult:
    """Attribute broadcast under an EngineConfig.  ``attr`` is an
    (M, n_loc) vertex attribute; ``state`` is the per-edge attribute
    aligned with pg.all_dst — (M, A_loc) padded layout, (E,) csr.
    stats['msgs_basic'] is the 3-superstep Pregel cost (request+response
    per edge, 2|E| messages); stats['msgs_rr'] the deduplicated Ch_req
    cost, identical across layouts and device counts.

    Ch_req is a pure gather with no combine stage, so ``backend`` does
    not change the path."""
    cfg = config or EngineConfig()
    devices = cfg.devices

    def make_fn(g):
        def fn(a):
            return gather_edges(g, a, g.all_dst, g.all_mask)
        return fn

    if devices is None:
        out, stats = jax.jit(make_fn(pg))(attr)
        return RunResult(state=out, stats=stats, n_supersteps=1)

    out, stats = exec_mod.apply_sharded(pg, make_fn, (attr,),
                                        devices=devices,
                                        pipeline=cfg.pipeline)
    if pg.layout == "csr":
        # sharded csr outputs come back device-concatenated with per-device
        # padding: strip back to the flat (E,) edge order (split partitions
        # place the device boundaries between physical shards)
        D, _ = exec_mod._normalize_devices(devices)
        bounds = exec_mod.device_edge_bounds(pg, devices)["all"]
        counts = np.diff(bounds)
        cap = out.shape[0] // D
        out = jax.numpy.concatenate(
            [out[d * cap:d * cap + int(counts[d])]
             for d in range(D)])
    return RunResult(state=out, stats=stats, n_supersteps=1)


def attribute_broadcast(pg: PartitionedGraph, attr,
                        backend: str = "dense",
                        devices: int | None = None,
                        pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns (edge_attr, stats).
    Use ``Engine.run("attr_bcast", ...)``."""
    warn_legacy("attribute_broadcast()", 'Engine.run("attr_bcast", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline), attr=attr)
    return res.state, res.stats

"""Attribute broadcast (paper §3.1): annotate every adjacency-list entry
(u in Γout(v)) with a(u).  The pure request-respond microbenchmark of
Fig. 13: per edge, v requests a(u) from u's owner; Ch_req dedups the
requests per (worker, target)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.channels import rr_gather, rr_gather_flat
from repro.graph.structs import PartitionedGraph


def attribute_broadcast(pg: PartitionedGraph, attr: jnp.ndarray,
                        backend: str = "dense"):
    """attr: (M, n_loc) vertex attribute.  Returns (edge_attr aligned with
    pg.all_dst — (M, A_loc) padded layout, (E,) csr layout — and stats).
    stats['msgs_basic'] is the 3-superstep Pregel cost (request+response
    per edge, 2|E| messages); stats['msgs_rr'] the deduplicated Ch_req
    cost, identical across layouts.

    ``backend`` is accepted for driver uniformity: Ch_req is a pure
    gather with no combine stage, so both backends share one path."""
    del backend
    if pg.layout == "csr":
        worker = pg.all_src // pg.n_loc
        fn = jax.jit(lambda a: rr_gather_flat(a, pg.all_dst, worker,
                                              pg.all_mask, pg.M, pg.n_loc))
    else:
        fn = jax.jit(lambda a: rr_gather(a, pg.all_dst, pg.all_mask,
                                         pg.M, pg.n_loc))
    out, stats = fn(attr)
    return out, stats

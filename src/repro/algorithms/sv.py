"""Shiloach-Vishkin connected components (paper §3.4) — the request-respond
showcase: every vertex u reads D[D[u]] from the owner of D[u], and towards
the end ALL vertices of a component request the same root (the Fig. 2
bottleneck).  Min-hooking variant (hook larger roots onto smaller labels),
which converges to the minimum id of each component in O(log n) rounds.

Message accounting: every pointer read is a request-respond exchange
(msgs_rr vs msgs_basic = the with/without-Ch_req comparison of Fig. 13);
hooking writes go through the combined scatter channel.

Labels are combined in int32 end to end (identity = iinfo sentinel, no
float32 round-trip): float32 cannot represent ids >= 2^24, so the old cast
merged distinct components on large graphs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import EngineConfig, RunResult, warn_legacy
from repro.core import bsp
from repro.core import exec as exec_mod
from repro.core.channels import broadcast, gather, scatter_state
from repro.core.plan import identity_of
from repro.graph.structs import PartitionedGraph


def _acc(stats, s, workers):
    """Accumulate a channel stats dict into uniform rr/basic counters."""
    rr = s.get("msgs_rr", s.get("msgs_combined", 0))
    stats["msgs_rr"] = stats.get("msgs_rr", 0) + rr
    stats["msgs_basic"] = stats.get("msgs_basic", 0) + s["msgs_basic"]
    pw_rr = s.get("per_worker_rr", s.get("per_worker_combined"))
    stats["per_worker_rr"] = stats.get("per_worker_rr",
                                       jnp.zeros(workers, jnp.int32)) + pw_rr
    stats["per_worker_basic"] = (stats.get("per_worker_basic",
                                           jnp.zeros(workers, jnp.int32))
                                 + s["per_worker_basic"])
    return stats


def run(pg: PartitionedGraph, config: EngineConfig | None = None, *,
        max_supersteps: int = 64) -> RunResult:
    """Shiloach-Vishkin under an EngineConfig.  ``state`` is the
    (M, n_loc) int32 label array (min id of each CC).  Pointer reads are
    request-respond exchanges, so ``use_mirroring`` does not apply."""
    cfg = config or EngineConfig()
    imax = identity_of("min", jnp.int32)
    backend = cfg.backend

    def make_step(g):
        M = g.M

        def step(state, i):
            D = state
            stats: dict = {}

            # D[D[u]]  — THE skewed pointer read (request-respond)
            DD, s = gather(g, D, D, g.vmask)
            stats = _acc(stats, s, M)
            parent_is_root = DD == D

            # cand[u] = min over neighbors v of D[v] (push D, min combiner,
            # in the id dtype — int32 identity, no float32 round-trip)
            cand_i, s = broadcast(g, D, g.vmask, op="min",
                                  use_mirroring=False, backend=backend)
            stats = _acc(stats, s, M)
            has_nbr = cand_i != imax
            cand = jnp.where(has_nbr, cand_i, 2 ** 30)

            # (1) tree hooking: roots get hooked onto smaller neighbor-parents
            hook_mask = g.vmask & parent_is_root & has_nbr & (cand < D)
            D1, s = scatter_state(g, D, D, cand, hook_mask, "min",
                                  backend=backend)
            stats = _acc(stats, s, M)

            # star detection on the hooked forest
            DD1, s = gather(g, D1, D1, g.vmask)
            stats = _acc(stats, s, M)
            star = (DD1 == D1).astype(jnp.int32)
            deep = g.vmask & (DD1 != D1)
            star, s = scatter_state(g, star, DD1, jnp.zeros_like(star),
                                    deep, "min", backend=backend)
            stats = _acc(stats, s, M)
            star_of_parent, s = gather(g, star, D1, g.vmask)
            stats = _acc(stats, s, M)
            in_star = g.vmask & (star_of_parent > 0)

            # (2) star hooking
            hook2 = in_star & has_nbr & (cand < D1)
            D2, s = scatter_state(g, D1, D1, cand, hook2, "min",
                                  backend=backend)
            stats = _acc(stats, s, M)

            # (3) shortcutting: D[u] = D[D[u]]
            DD2, s = gather(g, D2, D2, g.vmask)
            stats = _acc(stats, s, M)
            D3 = jnp.where(g.vmask, jnp.minimum(D2, DD2), D)

            halted = (g.gall(D3 == D) & ~g.gany(hook_mask)
                      & ~g.gany(hook2))
            return D3, halted, stats
        return step

    D0 = pg.local_ids().astype(jnp.int32)
    if cfg.devices is None:
        D, stats, n, _ = bsp.run(jax.jit(make_step(pg)), D0, max_supersteps,
                                 pipeline=cfg.pipeline)
    else:
        D, stats, n, _ = exec_mod.run_sharded(
            pg, make_step, D0, max_supersteps, devices=cfg.devices,
            plan_kinds=exec_mod.broadcast_plan_kinds(
                backend, use_mirroring=False),
            pipeline=cfg.pipeline)
    return RunResult(state=D, stats=stats, n_supersteps=n)


def sv(pg: PartitionedGraph, max_supersteps: int = 64,
       backend: str = "dense", devices: int | None = None,
       pipeline: bool = False):
    """Deprecated positional-tuple wrapper: returns (labels, stats,
    rounds).  Use ``Engine.run("sv", ...)``."""
    warn_legacy("sv()", 'Engine.run("sv", ...)')
    res = run(pg, EngineConfig(backend=backend, devices=devices,
                               pipeline=pipeline),
              max_supersteps=max_supersteps)
    return res.state, res.stats, res.n_supersteps

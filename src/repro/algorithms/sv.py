"""Shiloach-Vishkin connected components (paper §3.4) — the request-respond
showcase: every vertex u reads D[D[u]] from the owner of D[u], and towards
the end ALL vertices of a component request the same root (the Fig. 2
bottleneck).  Min-hooking variant (hook larger roots onto smaller labels),
which converges to the minimum id of each component in O(log n) rounds.

Message accounting: every pointer read is a request-respond exchange
(msgs_rr vs msgs_basic = the with/without-Ch_req comparison of Fig. 13);
hooking writes go through the combined scatter channel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bsp
from repro.core.channels import (broadcast, push_combined, rr_gather,
                                 scatter_combine)
from repro.graph.structs import PartitionedGraph


def _acc(stats, s, workers):
    """Accumulate a channel stats dict into uniform rr/basic counters."""
    rr = s.get("msgs_rr", s.get("msgs_combined", 0))
    stats["msgs_rr"] = stats.get("msgs_rr", 0) + rr
    stats["msgs_basic"] = stats.get("msgs_basic", 0) + s["msgs_basic"]
    pw_rr = s.get("per_worker_rr", s.get("per_worker_combined"))
    stats["per_worker_rr"] = stats.get("per_worker_rr",
                                       jnp.zeros(workers, jnp.int32)) + pw_rr
    stats["per_worker_basic"] = (stats.get("per_worker_basic",
                                           jnp.zeros(workers, jnp.int32))
                                 + s["per_worker_basic"])
    return stats


def sv(pg: PartitionedGraph, max_supersteps: int = 64,
       backend: str = "dense"):
    """Returns (labels (M, n_loc) int32 = min id of each CC, stats, rounds)."""
    ids = pg.local_ids().astype(jnp.int32)
    M, n_loc = pg.M, pg.n_loc
    widx = jnp.arange(M)[:, None]

    def step(state, i):
        D = state
        stats: dict = {}

        # D[D[u]]  — THE skewed pointer read (request-respond)
        DD, s = rr_gather(D, D, pg.vmask, M, n_loc)
        stats = _acc(stats, s, M)
        parent_is_root = DD == D

        # cand[u] = min over neighbors v of D[v] (push D with min combiner)
        cand_f, s = broadcast(pg, D.astype(jnp.float32), pg.vmask, op="min",
                              use_mirroring=False, backend=backend)
        stats = _acc(stats, s, M)
        has_nbr = jnp.isfinite(cand_f)
        cand = jnp.where(has_nbr, cand_f, 2 ** 30).astype(jnp.int32)

        # (1) tree hooking: roots get hooked onto smaller neighbor-parents
        hook_mask = pg.vmask & parent_is_root & has_nbr & (cand < D)
        D1, s = scatter_combine(D, D, cand, hook_mask, "min", M, n_loc,
                                backend=backend)
        stats = _acc(stats, s, M)

        # star detection on the hooked forest
        DD1, s = rr_gather(D1, D1, pg.vmask, M, n_loc)
        stats = _acc(stats, s, M)
        star = (DD1 == D1).astype(jnp.int32)
        deep = pg.vmask & (DD1 != D1)
        star, s = scatter_combine(star, DD1, jnp.zeros_like(star), deep,
                                  "min", M, n_loc, backend=backend)
        stats = _acc(stats, s, M)
        star_of_parent, s = rr_gather(star, D1, pg.vmask, M, n_loc)
        stats = _acc(stats, s, M)
        in_star = pg.vmask & (star_of_parent > 0)

        # (2) star hooking
        hook2 = in_star & has_nbr & (cand < D1)
        D2, s = scatter_combine(D1, D1, cand, hook2, "min", M, n_loc,
                                backend=backend)
        stats = _acc(stats, s, M)

        # (3) shortcutting: D[u] = D[D[u]]
        DD2, s = rr_gather(D2, D2, pg.vmask, M, n_loc)
        stats = _acc(stats, s, M)
        D3 = jnp.where(pg.vmask, jnp.minimum(D2, DD2), D)

        halted = jnp.all(D3 == D) & jnp.all(~hook_mask) & jnp.all(~hook2)
        return D3, halted, stats

    D0 = jnp.where(pg.vmask, ids, ids)
    return bsp.run(jax.jit(step), D0, max_supersteps)

"""Stage-structured transformer backbone for every assigned architecture.

A model is a list of **stages**; each stage is a stack of homogeneous layers
whose parameters are stacked on a leading axis and applied with ``lax.scan``
(compile time stays O(#stage kinds), not O(#layers)).  Heterogeneous layer
patterns (gemma3's 5:1 local:global windows, hymba's sparse global layers)
become multiple stages; caches are per-stage so sliding-window stages only
hold ``window`` KV slots — that is what makes ``long_500k`` sub-quadratic.

Modes:
  train   — full causal forward, logits for the shifted-token loss
  prefill — same forward, also emits the KV/SSM caches + last-position logits
  decode  — one token against the caches (ring-buffer windows, SSM state)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import (AttnSpec, NEG_INF, apply_rope, attn_block,
                                 rms_norm, swiglu)
from repro.models.moe import MoEContext, moe_ffn_ep, moe_ffn_ref
from repro.models.ssm import mamba_block


@dataclasses.dataclass(frozen=True)
class StageSpec:
    kind: str        # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'enc' | 'dec_cross'
    n_layers: int
    window: int = 0  # 0 = global attention


def build_stages(cfg: ArchConfig) -> List[StageSpec]:
    if cfg.family == "ssm":
        return [StageSpec("ssm", cfg.n_layers)]
    if cfg.is_moe:
        return [StageSpec("moe", cfg.n_layers)]
    kind = "hybrid" if cfg.family == "hybrid" else "dense"
    if cfg.enc_dec:
        kind = "dec_cross"
    if not cfg.sliding_window:
        return [StageSpec(kind, cfg.n_layers)]
    stages, run_w, run_n = [], None, 0
    for i in range(1, cfg.n_layers + 1):
        w = 0 if (cfg.global_every and i % cfg.global_every == 0) else cfg.sliding_window
        if w == run_w:
            run_n += 1
        else:
            if run_n:
                stages.append(StageSpec(kind, run_n, run_w))
            run_w, run_n = w, 1
    stages.append(StageSpec(kind, run_n, run_w))
    return stages


def enc_stage(cfg: ArchConfig) -> Optional[StageSpec]:
    return StageSpec("enc", cfg.n_enc_layers) if cfg.enc_dec else None


@dataclasses.dataclass(frozen=True)
class ModelContext:
    """Distribution/implementation knobs. mesh=None => local smoke mode."""
    mesh: Optional[Any] = None
    dp_axes: tuple = ("data",)
    ep_axis: str = "model"
    embed_method: str = "rr"       # gather | onehot | rr  (paper technique)
    remat: str = "full"            # 'full' | 'dots' | 'none'
    q_chunk: int = 1024
    # causal/window skip through static per-chunk KV slices (exact but
    # measured slower on the dry-run byte metric — §Perf iterations 3/4)
    attn_sliced: bool = False
    # scan=True keeps compile time O(1) in depth; the dry-run unrolls
    # (False) because XLA's HloCostAnalysis visits while bodies only once.
    scan_layers: bool = True

    @property
    def n_devices(self) -> int:
        return math.prod(self.mesh.shape.values()) if self.mesh is not None else 1


def _attn_spec(cfg, window, causal=True, ctx: ModelContext = None):
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                    causal=causal, window=window,
                    q_chunk=(ctx.q_chunk if ctx else 1024),
                    sliced=(ctx.attn_sliced if ctx else True))


def _moe_call(x2d, w, cfg: ArchConfig, ctx: ModelContext):
    if ctx.mesh is None:
        return moe_ffn_ref(x2d, w, cfg.moe)
    mctx = MoEContext(mesh=ctx.mesh, ep_axis=ctx.ep_axis, dp_axes=ctx.dp_axes)
    return moe_ffn_ep(x2d, w, cfg.moe, mctx)


def _cross_attend(h, w, spec, cfg, q_pos, enc_out):
    B = h.shape[0]
    cpos = jnp.broadcast_to(jnp.arange(enc_out.shape[1])[None],
                            (B, enc_out.shape[1]))
    ck = jnp.einsum("bsd,dhk->bshk", enc_out, w["cross"]["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", enc_out, w["cross"]["wv"])
    cspec = dataclasses.replace(spec, causal=False, window=0)
    return attn_block(rms_norm(h, w["norm_cross"], cfg.norm_eps),
                      w["cross"], cspec, q_pos,
                      cross_kv=(ck, cv), cross_pos=cpos)


# ---------------------------------------------------------------------------
# full-sequence stage application (train / prefill)
# ---------------------------------------------------------------------------

def apply_stage_seq(h, sp, stage: StageSpec, cfg: ArchConfig,
                    ctx: ModelContext, positions,
                    enc_out=None, want_cache=False, cache_len=0):
    """Run one stacked stage over the full sequence.
    Returns (h, stacked_layer_caches: dict, aux_loss: scalar)."""
    spec = _attn_spec(cfg, stage.window, causal=stage.kind != "enc", ctx=ctx)
    B, S, D = h.shape
    T_pad = ctx.n_devices

    def layer(h, w):
        aux = jnp.zeros((), jnp.float32)
        cache = {}
        if stage.kind == "ssm":
            xn = rms_norm(h, w["norm1"], cfg.norm_eps)
            y, (cst, sst) = mamba_block(xn, w["ssm"], cfg.ssm, cfg.d_model)
            h = h + y
            if want_cache:
                cache = {"conv": cst, "state": sst}
            return h, aux, cache
        if stage.kind == "hybrid":
            xn = rms_norm(h, w["norm1"], cfg.norm_eps)
            a = attn_block(xn, w["attn"], spec, positions,
                           return_kv=want_cache)
            if want_cache:
                a, (kf, vf) = a
            m, (cst, sst) = mamba_block(xn, w["ssm"], cfg.ssm, cfg.d_model)
            h = h + a + m
            h = h + swiglu(rms_norm(h, w["norm2"], cfg.norm_eps), w["mlp"])
            if want_cache:
                kc, vc = _tail_cache(kf, vf, cache_len)
                cache = {"k": kc, "v": vc, "conv": cst, "state": sst}
            return h, aux, cache
        # attention-based stages
        xn = rms_norm(h, w["norm1"], cfg.norm_eps)
        a = attn_block(xn, w["attn"], spec, positions, return_kv=want_cache)
        if want_cache:
            a, (kf, vf) = a
        h = h + a
        if stage.kind == "dec_cross":
            h = h + _cross_attend(h, w, spec, cfg, positions, enc_out)
        if stage.kind == "moe":
            xm = rms_norm(h, w["norm2"], cfg.norm_eps)
            x2 = xm.reshape(B * S, D)
            if (B * S) % T_pad:
                x2 = jnp.pad(x2, ((0, T_pad - (B * S) % T_pad), (0, 0)))
            y, aux = _moe_call(x2, w["moe"], cfg, ctx)
            h = h + y[:B * S].reshape(B, S, D)
        else:
            h = h + swiglu(rms_norm(h, w["norm2"], cfg.norm_eps), w["mlp"])
        if want_cache:
            kc, vc = _tail_cache(kf, vf, cache_len)
            cache = {"k": kc, "v": vc}
        return h, aux, cache

    run = layer
    if not want_cache and ctx.remat != "none":
        if ctx.remat == "dots":
            run = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            run = jax.checkpoint(layer)

    def scan_body(carry, w):
        h, aux_acc = carry
        h2, aux, cache = run(h, w)
        return (h2, aux_acc + aux), cache

    if ctx.scan_layers:
        (h, aux_total), caches = lax.scan(
            scan_body, (h, jnp.zeros((), jnp.float32)), sp["layers"])
        return h, caches, aux_total
    aux_total = jnp.zeros((), jnp.float32)
    per_layer = []
    for i in range(stage.n_layers):
        w_i = jax.tree.map(lambda x, i=i: x[i], sp["layers"])
        h, aux, cache = run(h, w_i)
        aux_total = aux_total + aux
        per_layer.append(cache)
    caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
              if per_layer and per_layer[0] else {})
    return h, caches, aux_total


def _tail_cache(k, v, cache_len: int):
    """Keep the last ``cache_len`` positions of already-computed rotated K/V
    in ring-buffer layout (slot = pos % cache_len)."""
    S = k.shape[1]
    if cache_len >= S:
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return k, v
    tail_k, tail_v = k[:, -cache_len:], v[:, -cache_len:]
    shift = S % cache_len
    return (jnp.roll(tail_k, shift, axis=1), jnp.roll(tail_v, shift, axis=1))


def stage_kpos(B: int, S: int, clen: int) -> jax.Array:
    """Positions held by each ring-buffer slot after prefilling S tokens."""
    slots = jnp.arange(clen)
    if clen >= S:
        return jnp.broadcast_to(jnp.where(slots < S, slots, -1), (B, clen))
    # largest p < S with p % clen == slot
    last = S - 1 - (S - 1 - slots) % clen
    p = jnp.where(last >= S, last - clen, last)
    return jnp.broadcast_to(p, (B, clen))


# ---------------------------------------------------------------------------
# single-token decode stage application
# ---------------------------------------------------------------------------

def apply_stage_decode(h, sp, stage: StageSpec, cfg: ArchConfig,
                       ctx: ModelContext, pos, cache, enc_out=None):
    """h: (B, 1, D); pos: (B,); cache: stage cache {layers..., 'k_pos'?}.
    Returns (h, new_cache)."""
    spec = _attn_spec(cfg, stage.window, ctx=ctx)
    B = h.shape[0]
    T_pad = ctx.n_devices
    k_pos = cache.get("k_pos")
    new_k_pos = None
    if k_pos is not None:
        clen = k_pos.shape[1]
        new_k_pos = k_pos.at[jnp.arange(B), pos % clen].set(pos)

    def attend_cached(xn, w, kc, vc):
        q = jnp.einsum("bsd,dhk->bshk", xn, w["wq"])
        q = apply_rope(q, pos[:, None], spec.rope_theta)
        k_new = apply_rope(jnp.einsum("bsd,dhk->bshk", xn, w["wk"]),
                           pos[:, None], spec.rope_theta)
        v_new = jnp.einsum("bsd,dhk->bshk", xn, w["wv"])
        clen = kc.shape[1]
        slot = pos % clen
        bidx = jnp.arange(B)
        kc = kc.at[bidx, slot].set(k_new[:, 0])
        vc = vc.at[bidx, slot].set(v_new[:, 0])
        n_rep = spec.n_heads // spec.n_kv_heads
        kf = jnp.repeat(kc, n_rep, axis=2) if n_rep > 1 else kc
        vf = jnp.repeat(vc, n_rep, axis=2) if n_rep > 1 else vc
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf,
                            preferred_element_type=jnp.float32) * spec.head_dim ** -0.5
        valid = (new_k_pos >= 0) & (new_k_pos <= pos[:, None])
        if spec.window:
            valid &= new_k_pos > (pos[:, None] - spec.window)
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vf.dtype), vf,
                       preferred_element_type=jnp.float32).astype(xn.dtype)
        return jnp.einsum("bshk,hkd->bsd", o, w["wo"]), kc, vc

    def layer(h, per_layer):
        w, lc = per_layer
        if stage.kind == "ssm":
            xn = rms_norm(h, w["norm1"], cfg.norm_eps)
            y, (cst, sst) = mamba_block(xn, w["ssm"], cfg.ssm, cfg.d_model,
                                        conv_state=lc["conv"],
                                        ssm_state=lc["state"], decode=True)
            return h + y, {"conv": cst, "state": sst}
        if stage.kind == "hybrid":
            xn = rms_norm(h, w["norm1"], cfg.norm_eps)
            a, kc, vc = attend_cached(xn, w["attn"], lc["k"], lc["v"])
            m, (cst, sst) = mamba_block(xn, w["ssm"], cfg.ssm, cfg.d_model,
                                        conv_state=lc["conv"],
                                        ssm_state=lc["state"], decode=True)
            h = h + a + m
            h = h + swiglu(rms_norm(h, w["norm2"], cfg.norm_eps), w["mlp"])
            return h, {"k": kc, "v": vc, "conv": cst, "state": sst}
        xn = rms_norm(h, w["norm1"], cfg.norm_eps)
        a, kc, vc = attend_cached(xn, w["attn"], lc["k"], lc["v"])
        h = h + a
        if stage.kind == "dec_cross":
            h = h + _cross_attend(h, w, spec, cfg, pos[:, None], enc_out)
        if stage.kind == "moe":
            xm = rms_norm(h, w["norm2"], cfg.norm_eps)
            x2 = xm.reshape(B, -1)
            if B % T_pad:
                x2 = jnp.pad(x2, ((0, T_pad - B % T_pad), (0, 0)))
            y, _ = _moe_call(x2, w["moe"], cfg, ctx)
            h = h + y[:B].reshape(B, 1, -1)
        else:
            h = h + swiglu(rms_norm(h, w["norm2"], cfg.norm_eps), w["mlp"])
        return h, {"k": kc, "v": vc}

    layer_caches = {k: v for k, v in cache.items() if k != "k_pos"}
    if ctx.scan_layers:
        h, new_caches = lax.scan(layer, h, (sp["layers"], layer_caches))
    else:
        per_layer = []
        for i in range(stage.n_layers):
            xi = jax.tree.map(lambda x, i=i: x[i], (sp["layers"], layer_caches))
            h, nc = layer(h, xi)
            per_layer.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    out = dict(new_caches)
    if new_k_pos is not None:
        out["k_pos"] = new_k_pos
    return h, out

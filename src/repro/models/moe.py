"""Mixture-of-Experts FFN with the paper's message-reduction techniques.

Mapping of Yan et al.'s ideas onto expert parallelism:

* **Sender-side message combining** (paper §4/§5): tokens headed to the same
  expert are packed into one contiguous per-(sender, expert) buffer *before*
  the ``all_to_all`` — one batched message per destination rank instead of
  one message per token, exactly the Pregel+ combined channel.
* **Mirroring** (paper §5, Thm 1/2 analog): the ``n_mirrored_experts``
  hottest experts are replicated on every EP rank; tokens routed to them are
  served locally and never enter the all_to_all, bounding the fan-in of a
  hot expert the same way a mirror bounds a high-degree vertex's fan-out.
  ``repro.core.cost_model.moe_mirror_threshold`` gives the Thm-2-style
  arbitration between replication (weight memory) and message savings.

Dispatch is capacity-bounded (static shapes): C tokens per (sender rank,
expert); overflow tokens are dropped with zero contribution — the standard
Switch/GShard semantics.  Two implementations with identical math:

* ``moe_ffn_ref``    — single-buffer reference (runs anywhere, oracle).
* ``moe_ffn_ep``     — shard_map expert-parallel version used under a mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig


@dataclasses.dataclass(frozen=True)
class MoEContext:
    """How the MoE layer is distributed. ep_axis is the mesh axis that shards
    experts; None means run the local reference path."""
    mesh: Optional[object] = None
    ep_axis: str = "model"
    dp_axes: tuple = ("data",)


def router_probs(x: jax.Array, w_router: jax.Array, top_k: int):
    """Return (gates, expert_idx): top-k router with renormalized softmax.
    x: (T, D), w_router: (D, E) -> gates (T, k), idx (T, k)."""
    logits = jnp.einsum("td,de->te", x, w_router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates.astype(x.dtype), idx, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-transformer auxiliary loss: E * <f_e> . <p_e>."""
    f = jnp.mean(jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum(1), axis=0)
    p = jnp.mean(probs.astype(jnp.float32), axis=0)
    return n_experts * jnp.sum(f * p)


def _expert_mlp(xe: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    """xe: (C, D) tokens for one expert."""
    g = jnp.einsum("cd,df->cf", xe, wg)
    u = jnp.einsum("cd,df->cf", xe, wu)
    return jnp.einsum("cf,fd->cd", jax.nn.silu(g) * u, wd)


def _pack(x, idx, gates, n_experts, cap, mirrored_mask):
    """Sender-side combining: scatter local tokens into a per-expert buffer.

    x: (T, D); idx/gates: (T, k). Returns:
      buf       (E, C, D) combined send buffer
      buf_gate  (E, C)    gate weight per slot
      buf_tok   (E, C)    source token index (for the return combine)
    Tokens whose expert is mirrored are EXCLUDED (mirrored_mask (E,) bool) —
    they never become network messages.
    """
    T, D = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                      # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    send = ~mirrored_mask[flat_e]
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32) * send[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot     # exclusive prefix count
    slot = (pos * onehot).sum(-1)                 # (T*k,)
    keep = send & (slot < cap)
    dest = jnp.where(keep, flat_e * cap + slot, n_experts * cap)  # overflow -> dropped row
    buf = jnp.zeros((n_experts * cap + 1, D), x.dtype).at[dest].add(x[flat_t])
    buf_gate = jnp.zeros((n_experts * cap + 1,), gates.dtype).at[dest].add(flat_g)
    buf_tok = jnp.full((n_experts * cap + 1,), -1, jnp.int32).at[dest].max(flat_t)
    return (buf[:-1].reshape(n_experts, cap, D),
            buf_gate[:-1].reshape(n_experts, cap),
            buf_tok[:-1].reshape(n_experts, cap))


def _unpack(y_buf, buf_gate, buf_tok, T, D):
    """Combine expert outputs back per source token (receiver-side combine)."""
    flat_y = y_buf.reshape(-1, D) * buf_gate.reshape(-1)[:, None]
    flat_t = buf_tok.reshape(-1)
    valid = flat_t >= 0
    tgt = jnp.where(valid, flat_t, T)
    out = jnp.zeros((T + 1, D), y_buf.dtype).at[tgt].add(flat_y)
    return out[:-1]


def moe_ffn_ref(x: jax.Array, w: dict, cfg: MoEConfig) -> tuple:
    """Reference single-worker dispatch. x: (T, D). w holds
    router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D)."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * T * k / E))
    gates, idx, probs = router_probs(x, w["router"], k)
    mirrored = jnp.zeros((E,), bool)
    buf, bg, bt = _pack(x, idx, gates, E, cap, mirrored)
    y_buf = jax.vmap(_expert_mlp)(buf, w["w_gate"], w["w_up"], w["w_down"])
    y = _unpack(y_buf, bg, bt, T, D)
    aux = load_balance_loss(probs, idx, E)
    return y, aux


def moe_ffn_ep(x: jax.Array, w: dict, cfg: MoEConfig, ctx: MoEContext) -> tuple:
    """Expert-parallel dispatch under shard_map.

    Token activations arrive sharded over dp axes and the ep axis (fully
    token-sharded); experts are sharded over ``ep_axis``. Per EP rank:
      route -> pack per-(rank,expert) combined buffers -> all_to_all(ep)
      -> local experts -> all_to_all back -> combine.
    Mirrored experts short-circuit the network entirely.
    """
    mesh = ctx.mesh
    ep = ctx.ep_axis
    E, k = cfg.n_experts, cfg.top_k
    ep_size = mesh.shape[ep]
    e_loc = E // ep_size
    n_m = min(cfg.n_mirrored_experts, E)

    def body(xs, router, wg, wu, wd, wgm, wum, wdm):
        # xs: (T_loc, D) local tokens; wg/...: (e_loc, D, F) local experts;
        # w*m: (n_m, D, F) mirrored (replicated) experts.
        T_loc, D = xs.shape
        cap = max(1, int(cfg.capacity_factor * T_loc * k / E))
        gates, idx, probs = router_probs(xs, router, k)
        mirrored = jnp.arange(E) < n_m  # hottest-first layout (see cost_model)
        buf, bg, bt = _pack(xs, idx, gates, E, cap, mirrored)
        # ---- network path: one combined message per (dst rank, expert) ----
        buf = buf.reshape(ep_size, e_loc, cap, D)
        recv = lax.all_to_all(buf, ep, split_axis=0, concat_axis=0, tiled=False)
        # recv: (ep_size_src, e_loc, cap, D) -> per local expert, all senders
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep_size * cap, D)
        y = jax.vmap(_expert_mlp)(recv, wg, wu, wd)
        y = y.reshape(e_loc, ep_size, cap, D).transpose(1, 0, 2, 3)
        y = lax.all_to_all(y, ep, split_axis=0, concat_axis=0, tiled=False)
        out = _unpack(y.reshape(E, cap, D), bg, bt, T_loc, D)
        # ---- mirrored path: local compute, zero messages ----
        for j in range(n_m):
            g = ((idx == j) * gates).sum(-1)
            out = out + _expert_mlp(xs, wgm[j], wum[j], wdm[j]) * g[:, None]
        aux = lax.pmean(load_balance_loss(probs, idx, E), (*dp, ep))
        return out, aux

    dp = ctx.dp_axes
    tok_spec = P((*dp, ep), None)
    exp_spec = P(ep, None, None)
    rep = P(None, None, None)
    from jax.experimental.shard_map import shard_map
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None), exp_spec, exp_spec, exp_spec,
                  rep, rep, rep),
        out_specs=(tok_spec, P()),
        check_rep=False,
    )(x, w["router"], w["w_gate"], w["w_up"], w["w_down"],
      w["w_gate_m"], w["w_up_m"], w["w_down_m"])
    return y, aux

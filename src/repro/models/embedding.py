"""Vocab-sharded embedding with the paper's request-respond lookup.

A vocab-sharded embedding table is the S-V access pattern of Yan et al. §6:
every token is a *requester* asking the owner shard of row ``id`` for its
value, and token frequency is Zipf-skewed, so a handful of rows are
bottleneck vertices.  Three lookup methods, worst first:

* ``gather``  — Pregel basic message passing: a plain ``take`` on the
  sharded table.  GSPMD resolves this by all-gathering the table
  (vocab x d_model bytes of collective traffic — the "blue bars").
* ``onehot``  — sender-side combining: each model rank computes
  ``onehot(ids) @ table_shard`` and the partial embeddings are psum'd;
  traffic drops from O(V.D) to O(T.D).
* ``rr``      — the request-respond channel: per shard, token ids are
  **deduplicated** (sort-based, static capacity U = min(T, V) which is an
  exact bound on distinct requests), one request per unique id is resolved
  via the onehot/psum combine, and the (U, D) *response table* is scattered
  back to tokens locally — Theorem 3's 2.min(M, l) bound with the response
  payload shrunk from T rows to U rows.

The logits projection shares the table (vocab-sharded); its softmax
reductions over the sharded vocab axis lower to scalar-sized all-reduces.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def dedup_ids(ids: jax.Array, capacity: int):
    """Sort-based fixed-capacity dedup (static shapes, jit-safe).

    ids: (T,) int32. Returns (uniq (capacity,), inv (T,)) such that
    ``uniq[inv] == ids``; unused uniq slots hold 0.  capacity must be
    >= number of distinct ids (capacity = min(T, vocab) always is).
    """
    T = ids.shape[0]
    order = jnp.argsort(ids)
    s = ids[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    # rank of each sorted element among uniques
    rank = jnp.cumsum(first) - 1                      # (T,)
    uniq = jnp.zeros((capacity,), ids.dtype).at[rank].max(s)
    inv = jnp.zeros((T,), rank.dtype).at[order].set(rank)
    n_uniq = rank[-1] + 1
    return uniq, inv, n_uniq


def embed_lookup(table: jax.Array, ids: jax.Array, method: str = "rr",
                 rr_capacity: int = 0) -> jax.Array:
    """table: (V, D) (vocab-sharded under jit); ids: (..., ) int32.

    Written with plain ops + sharding-friendly one-hot contractions; under
    pjit the table stays vocab-sharded and only combined partial sums move.
    """
    shape = ids.shape
    flat = ids.reshape(-1)
    V, D = table.shape
    if method == "gather":
        out = jnp.take(table, flat, axis=0)
    elif method == "onehot":
        oh = jax.nn.one_hot(flat, V, dtype=table.dtype)
        out = jnp.einsum("tv,vd->td", oh, table)
    elif method == "rr":
        cap = rr_capacity or min(flat.shape[0], V)
        uniq, inv, _ = dedup_ids(flat, cap)
        oh = jax.nn.one_hot(uniq, V, dtype=table.dtype)
        resp = jnp.einsum("uv,vd->ud", oh, table)  # response table (U, D)
        out = jnp.take(resp, inv, axis=0)          # local scatter to requesters
    else:
        raise ValueError(method)
    return out.reshape(*shape, D)


def embed_lookup_sharded(table: jax.Array, ids: jax.Array, mesh,
                         dp_axes: tuple, mp_axis: str = "model"
                         ) -> jax.Array:
    """Paper-faithful request-respond lookup under a mesh: each data-parallel
    *worker* dedups its own token ids (the per-worker request set of §6),
    resolves one request per distinct id against the vocab-sharded table
    (one-hot partial + psum over the model axis = the response exchange),
    and scatters the (U, D) response table back to its tokens locally.

    Crucially the dedup is per shard, so batch sharding survives the
    embedding (a global argsort would force GSPMD to replicate the batch —
    the defect this replaced; see EXPERIMENTS.md §Dry-run)."""
    B, S = ids.shape
    V, D = table.shape
    mp = mesh.shape[mp_axis]
    dp_size = math.prod(mesh.shape[a] for a in dp_axes)
    if B % dp_size or V % mp:
        # fall back: local dedup semantics with a sharding constraint
        from jax.sharding import NamedSharding
        out = embed_lookup(table, ids, method="rr")
        return lax.with_sharding_constraint(
            out, NamedSharding(mesh, P(None, None, None)))
    v_loc = V // mp

    def body(ids_loc, table_loc):
        flat = ids_loc.reshape(-1)                    # (T_loc,)
        cap = min(flat.shape[0], V)
        uniq, inv, _ = dedup_ids(flat, cap)           # per-WORKER request set
        vstart = lax.axis_index(mp_axis) * v_loc
        cols = vstart + jnp.arange(v_loc)
        oh = (uniq[:, None] == cols[None, :]).astype(table_loc.dtype)
        part = jnp.einsum("uv,vd->ud", oh, table_loc)  # local response rows
        resp = lax.psum(part, mp_axis)                 # response exchange
        out = jnp.take(resp, inv, axis=0)              # local scatter
        return out.reshape(ids_loc.shape[0], S, D)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, None), P(mp_axis, None)),
        out_specs=P(dp_axes, None, None),
        check_rep=False,
    )(ids, table)


def logits_matmul(h: jax.Array, table: jax.Array) -> jax.Array:
    """h: (B, S, D) -> logits (B, S, V), vocab axis stays sharded."""
    return jnp.einsum("bsd,vd->bsv", h, table,
                      preferred_element_type=jnp.float32)


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array):
    """Cross-entropy over (possibly vocab-sharded) logits.

    logits: (B, S, V) fp32; labels: (B, S) int32; mask: (B, S) {0,1}.
    The max/sum reductions over V lower to tiny all-reduces when V is
    sharded; the label pick uses a one-hot contraction (shard-friendly).
    """
    V = logits.shape[-1]
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    oh = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    picked = jnp.sum(logits * oh, axis=-1)
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Graph-node embeddings on the BSP engine (vector Ch_req payloads)
# ---------------------------------------------------------------------------

def node_embedding_init(pg, feat_dim: int, seed: int = 0,
                        scale: float | None = None,
                        dtype=jnp.float32) -> jax.Array:
    """Worker-sharded node-embedding table for a partitioned graph.

    Returns a ``(M, n_loc, feat_dim)`` array — the engine's row-state
    shape with ONE trailing feature axis, i.e. exactly the vector-payload
    convention every channel accepts.  Rows are N(0, scale) for real
    vertices (``scale`` defaults to ``feat_dim**-0.5``) and zero for the
    layout's padding slots, so padded rows contribute nothing to joins.
    The init is a function of the ORIGINAL vertex id (placed through
    ``pg.perm``): two partitions of the same graph start from the same
    embedding for every vertex, which is what the sharded-vs-unsharded
    gradient-parity tests rely on."""
    import numpy as np
    if scale is None:
        scale = float(feat_dim) ** -0.5
    rng = np.random.RandomState(seed)
    rows = rng.randn(pg.n, feat_dim).astype(np.float32) * scale
    tab = np.zeros((pg.n_pad, feat_dim), np.float32)
    tab[np.asarray(pg.perm)] = rows
    return jnp.asarray(tab, dtype).reshape(pg.M, pg.n_loc, feat_dim)


def node_embedding_fetch(g, table: jax.Array, ids: jax.Array,
                         mask: jax.Array):
    """Sparse embedding lookup over the request-respond channel.

    ``table`` is the sharded ``(rows, n_loc, F)`` node table; ``ids``
    ``(rows, R)`` global (padded) vertex ids each worker wants rows for.
    This is the S-V access pattern of §6 with a VECTOR payload: requests
    are deduplicated per worker, the owner responds once per distinct id
    with the full ``(F,)`` block, and the response table is scattered back
    locally — returns ``((rows, R, F) values, stats)``.  Works unsharded
    (PartitionedGraph) and inside ``shard_map`` (ShardedGraph), where the
    respond leg lowers to the routed (lanes, F) exchange."""
    from repro.core import channels
    return channels.gather(g, table, ids, mask)

"""Mamba-2 SSD (state-space duality) block: chunked parallel scan for
train/prefill, O(1)-state recurrent step for decode.

Math (per head h, state dim N, head dim P):
    S_t = exp(dt_t * A) * S_{t-1} + dt_t * B_t x_t^T        (S: P x N)
    y_t = C_t . S_t + D_skip * x_t

The chunked algorithm follows arXiv:2405.21060 §6: within-chunk attention-like
term via the 1-semiseparable mask L = exp(segsum(dtA)), plus inter-chunk
state recurrence.  A naive recurrent oracle lives in
``repro.kernels.ssd_scan.ref`` (tests assert allclose, and the Pallas kernel
tiles the same chunk structure for VMEM).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]
    (lower-triangular; -inf above the diagonal)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x: (b, s, h, p); dt: (b, s, h) (already softplus'd, >0);
    A: (h,) (negative); B, C: (b, s, g, n) with h % g == 0.
    Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # chunk reshape: (b, c, l, ...)
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,c,l,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dtA = (dtc * A[None, None, None, :]).astype(jnp.float32)  # (b,c,l,h) <= 0
    xdt = xc * dtc[..., None].astype(xc.dtype)

    # ---- intra-chunk (diagonal) term -------------------------------------
    Lmat = jnp.exp(segsum(dtA.transpose(0, 1, 3, 2)))  # (b,c,h,l,l)
    scores = jnp.einsum("bclhn,bcmhn->bchlm", Ch, Bh,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchlm,bchlm,bcmhp->bclhp", scores, Lmat,
                        xdt.astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    # ---- per-chunk final states ------------------------------------------
    cum = jnp.cumsum(dtA, axis=2)                       # (b,c,l,h)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (b,c,l,h)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh,
                        decay_to_end, xdt.astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # (b,c,h,p,n)

    # ---- inter-chunk recurrence (scan over chunks) ------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (b,c,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = st + dec[:, :, None, None] * carry
        return new, carry  # emit state *entering* the chunk

    final, prev_states = lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # ---- inter-chunk (off-diagonal) output term ---------------------------
    decay_from_start = jnp.exp(cum)                      # (b,c,l,h)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp", Ch, decay_from_start,
                       prev_states, preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
    return y, final.astype(jnp.float32)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B: jax.Array, C: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrent step. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    B, C: (b,g,n). Returns (y (b,h,p), new_state)."""
    g = B.shape[1]
    rep = state.shape[1] // g
    Bh = jnp.repeat(B, rep, axis=1)  # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    dtA = (dt * A[None, :]).astype(jnp.float32)
    new = (jnp.exp(dtA)[:, :, None, None] * state
           + (dt.astype(jnp.float32))[:, :, None, None]
           * x.astype(jnp.float32)[:, :, :, None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), new


# ---------------------------------------------------------------------------
# Full Mamba-2 block (projections + causal depthwise conv + SSD + gate)
# ---------------------------------------------------------------------------

def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           state: Optional[jax.Array] = None):
    """x: (b, s, c); w: (width, c). Returns (y, new_state (b, width-1, c))."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(width):  # width is 4: unrolled taps
        y = y + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


def mamba_block(x: jax.Array, w: dict, cfg: SSMConfig, d_model: int,
                conv_state=None, ssm_state=None, decode: bool = False):
    """Mamba-2 mixer. x: (b, s, d_model). Weights:
      wz/wx (D, d_inner), wB/wC (D, g*n), wdt (D, h),
      conv_x (width, d_inner), conv_B/conv_C (width, g*n),
      A_log (h,), D_skip (h,), dt_bias (h,), norm (d_inner,),
      out_proj (d_inner, D).
    Returns (y, (conv_states, ssm_state)).
    """
    b, s, _ = x.shape
    d_inner = w["wx"].shape[1]
    h = w["A_log"].shape[0]
    p = d_inner // h
    g = w["wB"].shape[1] // cfg.d_state
    n = cfg.d_state

    z = jnp.einsum("bsd,de->bse", x, w["wz"])
    xs = jnp.einsum("bsd,de->bse", x, w["wx"])
    Bv = jnp.einsum("bsd,de->bse", x, w["wB"])
    Cv = jnp.einsum("bsd,de->bse", x, w["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, w["wdt"])

    cs = conv_state if conv_state is not None else (None, None, None)
    xs, cx = _causal_depthwise_conv(xs, w["conv_x"], cs[0])
    Bv, cb = _causal_depthwise_conv(Bv, w["conv_B"], cs[1])
    Cv, cc = _causal_depthwise_conv(Cv, w["conv_C"], cs[2])
    xs, Bv, Cv = jax.nn.silu(xs), jax.nn.silu(Bv), jax.nn.silu(Cv)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + w["dt_bias"].astype(jnp.float32)[None, None])
    A = -jnp.exp(w["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, s, h, p)
    Bh = Bv.reshape(b, s, g, n)
    Ch = Cv.reshape(b, s, g, n)

    if decode:
        y1, new_state = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0].astype(jnp.float32), A,
            Bh[:, 0], Ch[:, 0])
        y = y1[:, None]
    else:
        chunk = cfg.chunk if s % cfg.chunk == 0 else s
        y, new_state = ssd_chunked(xh, dt.astype(jnp.float32), A, Bh, Ch,
                                   chunk, init_state=ssm_state)
    y = y + xh * w["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), w["norm"])
    out = jnp.einsum("bse,ed->bsd", y, w["out_proj"])
    return out, ((cx, cb, cc), new_state)

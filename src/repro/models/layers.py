"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / sliding /
chunked online-softmax), SwiGLU MLP.

Pure-functional: params are dict pytrees; every function takes stacked
per-layer weights so the caller can ``lax.scan`` over a homogeneous stage.
All matmuls accumulate in fp32 (``preferred_element_type``) and activations
stay in the config compute dtype (bf16 for the full configs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -2.0 ** 30  # large-but-finite: keeps softmax NaN-free on fully masked rows


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    causal: bool = True
    window: int = 0          # sliding window size; 0 = full
    q_chunk: int = 1024      # online-softmax query-chunking threshold/size
    # causal/window skip via static per-chunk slices — REFUTED on the
    # CPU-HLO byte metric (§Perf iterations 3/4: slicing tripled measured
    # traffic vs the scan path); kept as an option for real-TPU profiling.
    sliced: bool = False


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)).reshape(b, s, kh * n_rep, hd)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int) -> jax.Array:
    """(…, Sq, Sk) additive bias from position vectors."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, dtype=bool)
    if causal:
        ok &= d >= 0
    if window > 0:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, spec: AttnSpec,
              q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    """Plain attention with *grouped-GQA einsums*: query heads are reshaped
    to (kv_head, rep) so repeated K/V are never materialized in HBM
    (§Perf: the repeat cost scales with S and dominated the sliced-attention
    attempt before this).  q: (B,Sq,H,hd), k/v: (B,Sk,K,hd)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    r = h // kh
    scale = spec.head_dim ** -0.5
    qg = q.reshape(b, sq, kh, r, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    bias = _mask_bias(q_pos, k_pos, spec.causal, spec.window)
    scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, spec: AttnSpec,
                      q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    """Online-softmax attention, scanned over query chunks.

    Memory: O(Sq_chunk * Sk) instead of O(Sq * Sk); the Pallas
    ``flash_attention`` kernel is the TPU-tiled version of this loop and is
    validated against it in tests.
    """
    b, sq, h, hd = q.shape
    c = min(spec.q_chunk, sq)
    if sq % c:
        return attention(q, k, v, spec, q_pos, k_pos)
    n_rep = spec.n_heads // spec.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = spec.head_dim ** -0.5
    qs = q.reshape(b, sq // c, c, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(b, sq // c, c).transpose(1, 0, 2)

    def body(_, qc):
        qi, qpi = qc
        scores = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                            preferred_element_type=jnp.float32) * scale
        scores = scores + _mask_bias(qpi, k_pos, spec.causal, spec.window)[:, None]
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        denom = jnp.sum(p, axis=-1).transpose(0, 2, 1)[..., None]
        return None, (o / jnp.maximum(denom, 1e-30)).astype(qi.dtype)

    _, out = lax.scan(body, None, (qs, qp))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def chunked_attention_sliced(q, k, v, spec: AttnSpec, q_pos, k_pos):
    """Python-loop query chunking with *static per-chunk KV slices*:
    chunk i attends keys [lo_i, hi_i) where hi_i is the causal frontier and
    lo_i the window tail — masked-out score blocks are never materialized.
    Exact (masking still applies inside the slice); halves causal score
    traffic and cuts windowed stages to O(window) per chunk.
    (§Perf iteration: 'causal skip')."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    c = min(spec.q_chunk, sq)
    if sq % c:
        return attention(q, k, v, spec, q_pos, k_pos)
    same_frame = sq == sk  # prefill/train: q i aligns with k i
    outs = []
    for i in range(sq // c):
        hi = (i + 1) * c if (spec.causal and same_frame) else sk
        lo = 0
        if spec.window and spec.causal and same_frame:
            lo = max(0, hi - spec.window - c)
        qi = q[:, i * c:(i + 1) * c]
        out = attention(qi, k[:, lo:hi], v[:, lo:hi], spec,
                        q_pos[:, i * c:(i + 1) * c], k_pos[:, lo:hi])
        outs.append(out)
    return jnp.concatenate(outs, axis=1)


def attn_qkv(x: jax.Array, w: dict, spec: AttnSpec, positions: jax.Array):
    """Project to rotated q and k, v. w['wq']:(D,H,hd) w['wk'/'wv']:(D,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, w["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, w["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, w["wv"])
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def attn_block(x: jax.Array, w: dict, spec: AttnSpec, positions: jax.Array,
               cross_kv: Optional[tuple] = None, cross_pos=None,
               return_kv: bool = False):
    """Full attention sub-block (no cache): qkv + attn + out-proj.
    return_kv=True also returns the rotated (k, v) so prefill can build the
    KV cache without recomputing the projections (§Perf iteration 2)."""
    if cross_kv is None:
        q, k, v = attn_qkv(x, w, spec, positions)
        k_pos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, w["wq"])
        q = apply_rope(q, positions, spec.rope_theta)
        k, v = cross_kv
        k_pos = cross_pos
    if x.shape[1] <= spec.q_chunk:
        impl = attention
    elif spec.sliced:
        impl = chunked_attention_sliced
    else:
        impl = chunked_attention
    o = impl(q, k, v, spec, positions, k_pos)
    out = jnp.einsum("bshk,hkd->bsd", o, w["wo"])
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# Decode-time attention against a KV cache
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     spec: AttnSpec, pos: jax.Array, cache_len: int) -> jax.Array:
    """One-token decode. q: (B,1,H,hd); caches: (B,Sc,K,hd); pos: (B,) current
    position (tokens < pos are valid). Works with the cache sequence axis
    sharded (GSPMD inserts small all-reduces for the softmax stats)."""
    n_rep = spec.n_heads // spec.n_kv_heads
    k = _repeat_kv(k_cache, n_rep)
    v = _repeat_kv(v_cache, n_rep)
    scale = spec.head_dim ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    k_idx = lax.broadcasted_iota(jnp.int32, (1, 1, 1, k.shape[1]), 3)
    valid = k_idx <= pos[:, None, None, None]
    if spec.window > 0:
        valid &= k_idx > (pos[:, None, None, None] - spec.window)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w: dict) -> jax.Array:
    """w['w_gate'/'w_up']: (D,F), w['w_down']: (F,D)."""
    g = jnp.einsum("bsd,df->bsf", x, w["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, w["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w["w_down"])

"""ArchConfig -> runnable model: param init (real or abstract), train loss,
prefill and decode entry points, KV/SSM cache construction.

Param pytree layout (all per-stage weights stacked on a leading layer axis):

    params = {
      'embed':      (V_pad, D),
      'out_embed':  (V_pad, D),            # == embed when tie_embeddings
      'final_norm': (D,),
      'stages':     [ {'layers': {...stacked...}}, ... ],
      'enc':        {'stages': [...], 'final_norm': (D,)}   # enc_dec only
    }
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import embedding as emb
from repro.models.layers import rms_norm
from repro.models.transformer import (ModelContext, StageSpec,
                                      apply_stage_decode, apply_stage_seq,
                                      build_stages, enc_stage, stage_kpos)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _attn_shapes(cfg: ArchConfig, L: int) -> Dict[str, tuple]:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {"wq": (L, D, H, hd), "wk": (L, D, K, hd),
            "wv": (L, D, K, hd), "wo": (L, H, hd, D)}


def _ssm_shapes(cfg: ArchConfig, L: int) -> Dict[str, tuple]:
    D = cfg.d_model
    di = cfg.d_inner
    g, n = cfg.ssm.n_groups, cfg.ssm.d_state
    h = cfg.n_ssm_heads
    w = cfg.ssm.conv_width
    return {"wz": (L, D, di), "wx": (L, D, di), "wB": (L, D, g * n),
            "wC": (L, D, g * n), "wdt": (L, D, h),
            "conv_x": (L, w, di), "conv_B": (L, w, g * n), "conv_C": (L, w, g * n),
            "A_log": (L, h), "D_skip": (L, h), "dt_bias": (L, h),
            "norm": (L, di), "out_proj": (L, di, D)}


def _mlp_shapes(cfg: ArchConfig, L: int) -> Dict[str, tuple]:
    D, F = cfg.d_model, cfg.d_ff
    return {"w_gate": (L, D, F), "w_up": (L, D, F), "w_down": (L, F, D)}


def _moe_shapes(cfg: ArchConfig, L: int) -> Dict[str, tuple]:
    D, E, F = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff_expert
    m = max(cfg.moe.n_mirrored_experts, 1)  # keep a non-empty leaf for pytrees
    return {"router": (L, D, E),
            "w_gate": (L, E, D, F), "w_up": (L, E, D, F), "w_down": (L, E, F, D),
            "w_gate_m": (L, m, D, F), "w_up_m": (L, m, D, F),
            "w_down_m": (L, m, F, D)}


def stage_param_shapes(cfg: ArchConfig, stage: StageSpec) -> Dict[str, Any]:
    L, D = stage.n_layers, cfg.d_model
    out: Dict[str, Any] = {"norm1": (L, D)}
    if stage.kind == "ssm":
        out["ssm"] = _ssm_shapes(cfg, L)
        return out
    out["norm2"] = (L, D)
    if stage.kind == "hybrid":
        out["attn"] = _attn_shapes(cfg, L)
        out["ssm"] = _ssm_shapes(cfg, L)
        out["mlp"] = _mlp_shapes(cfg, L)
        return out
    out["attn"] = _attn_shapes(cfg, L)
    if stage.kind == "moe":
        out["moe"] = _moe_shapes(cfg, L)
    else:
        out["mlp"] = _mlp_shapes(cfg, L)
    if stage.kind == "dec_cross":
        out["norm_cross"] = (L, D)
        out["cross"] = _attn_shapes(cfg, L)
    return out


def param_shapes(cfg: ArchConfig, model_parallel: int = 1) -> Dict[str, Any]:
    V = cfg.padded_vocab(model_parallel)
    D = cfg.d_model
    shapes: Dict[str, Any] = {
        "embed": (V, D),
        "out_embed": (V, D),
        "final_norm": (D,),
        "stages": [{"layers": stage_param_shapes(cfg, s)}
                   for s in build_stages(cfg)],
    }
    es = enc_stage(cfg)
    if es is not None:
        shapes["enc"] = {"stages": [{"layers": stage_param_shapes(cfg, es)}],
                         "final_norm": (D,)}
    return shapes


_NO_INIT_SCALE = {"norm1", "norm2", "norm_cross", "final_norm", "norm",
                  "A_log", "D_skip", "dt_bias"}


def init_params(cfg: ArchConfig, key: jax.Array, model_parallel: int = 1,
                dtype=jnp.float32) -> Dict[str, Any]:
    """Real initialization (smoke tests / the training examples)."""
    shapes = param_shapes(cfg, model_parallel)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes,
                                                           is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    D = cfg.d_model

    def make(path, shape, k):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("norm1", "norm2", "norm_cross", "final_norm", "norm"):
            return jnp.zeros(shape, dtype)
        if name == "A_log":
            return jnp.log(jnp.broadcast_to(
                jnp.arange(1, shape[-1] + 1, dtype=jnp.float32), shape)).astype(jnp.float32)
        if name == "D_skip":
            return jnp.ones(shape, jnp.float32)
        if name == "dt_bias":
            return jnp.full(shape, math.log(math.expm1(0.01)), jnp.float32)
        fan_in = shape[-2] if len(shape) >= 2 else D
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    vals = [make(p, s, k) for (p, s), k in zip(leaves, keys)]
    params = jax.tree_util.tree_unflatten(treedef, vals)
    if cfg.tie_embeddings:
        params["out_embed"] = params["embed"]
    return params


def abstract_params(cfg: ArchConfig, model_parallel: int = 1,
                    dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation).
    Norm-ish / SSM scalar-family params stay fp32 (matching init)."""
    def make(path, shape):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        dt = jnp.float32 if name in _NO_INIT_SCALE else dtype
        return jax.ShapeDtypeStruct(shape, dt)

    shapes = param_shapes(cfg, model_parallel)
    return jax.tree_util.tree_map_with_path(
        make, shapes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, ids, ctx: ModelContext):
    if ctx.mesh is not None and ctx.embed_method == "rr" and ids.ndim == 2:
        h = emb.embed_lookup_sharded(params["embed"], ids, ctx.mesh,
                                     ctx.dp_axes, ctx.ep_axis)
    else:
        h = emb.embed_lookup(params["embed"], ids, method=ctx.embed_method)
    if cfg.tie_embeddings:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def _run_encoder(params, cfg, ctx, enc_embeds):
    es = enc_stage(cfg)
    B, Se, _ = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    h = enc_embeds
    h, _, _ = apply_stage_seq(h, params["enc"]["stages"][0], es, cfg, ctx, pos)
    return rms_norm(h, params["enc"]["final_norm"], cfg.norm_eps)


def _mask_pad_vocab(logits, vocab):
    V = logits.shape[-1]
    if V == vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < vocab, logits, NEG_INF_F32)


NEG_INF_F32 = -2.0 ** 30


def forward_logits(params, cfg: ArchConfig, ctx: ModelContext, tokens,
                   enc_embeds=None):
    """tokens: (B, S) -> logits (B, S, V_pad) fp32 (vocab-sharded under jit)."""
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = _embed_in(params, cfg, tokens, ctx)
    enc_out = _run_encoder(params, cfg, ctx, enc_embeds) if cfg.enc_dec else None
    aux_total = jnp.zeros((), jnp.float32)
    for sp, stage in zip(params["stages"], build_stages(cfg)):
        h, _, aux = apply_stage_seq(h, sp, stage, cfg, ctx, pos, enc_out=enc_out)
        aux_total = aux_total + aux
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = emb.logits_matmul(h, params["out_embed"])
    return _mask_pad_vocab(logits, cfg.vocab), aux_total


def loss_fn(params, cfg: ArchConfig, ctx: ModelContext, batch,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    tokens = batch["tokens"]
    logits, aux = forward_logits(params, cfg, ctx, tokens,
                                 enc_embeds=batch.get("enc_embeds"))
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    nll = emb.softmax_xent(logits, labels, mask)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache build, prefill, decode
# ---------------------------------------------------------------------------

def _stage_cache_len(stage: StageSpec, seq_len: int) -> int:
    return min(stage.window, seq_len) if stage.window else seq_len


def build_cache(cfg: ArchConfig, B: int, seq_len: int, ctx: ModelContext,
                dtype=jnp.bfloat16, abstract: bool = False):
    """Cache pytree (arrays or ShapeDtypeStructs) for decode at context
    ``seq_len``."""
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
         (lambda s, dt: jnp.zeros(s, dt))
    K, hd = cfg.n_kv_heads, cfg.hd
    caches = []
    for stage in build_stages(cfg):
        L = stage.n_layers
        c: Dict[str, Any] = {}
        clen = _stage_cache_len(stage, seq_len)
        if stage.kind in ("dense", "moe", "dec_cross", "hybrid"):
            c["k"] = mk((L, B, clen, K, hd), dtype)
            c["v"] = mk((L, B, clen, K, hd), dtype)
            c["k_pos"] = mk((B, clen), jnp.int32)
        if stage.kind in ("ssm", "hybrid"):
            di, gn = cfg.d_inner, cfg.ssm.n_groups * cfg.ssm.d_state
            w = cfg.ssm.conv_width
            c["conv"] = (mk((L, B, w - 1, di), dtype),
                         mk((L, B, w - 1, gn), dtype),
                         mk((L, B, w - 1, gn), dtype))
            c["state"] = mk((L, B, cfg.n_ssm_heads,
                             cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32)
        caches.append(c)
    out = {"stages": caches, "pos": mk((B,), jnp.int32)}
    if cfg.enc_dec:
        out["enc_out"] = mk((B, cfg.enc_seq, cfg.d_model), dtype)
    return out


def prefill(params, cfg: ArchConfig, ctx: ModelContext, tokens,
            enc_embeds=None, max_len: int = 0):
    """tokens: (B, S). Returns (last-token logits (B, V), cache).

    ``max_len`` sets global-attention cache capacity (>= S + expected decode
    steps); window stages always hold exactly ``window`` slots."""
    B, S = tokens.shape
    max_len = max(max_len, S)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = _embed_in(params, cfg, tokens, ctx)
    enc_out = _run_encoder(params, cfg, ctx, enc_embeds) if cfg.enc_dec else None
    caches = []
    for sp, stage in zip(params["stages"], build_stages(cfg)):
        clen = _stage_cache_len(stage, max_len)
        h, cache, _ = apply_stage_seq(h, sp, stage, cfg, ctx, pos,
                                      enc_out=enc_out, want_cache=True,
                                      cache_len=clen)
        if stage.kind != "ssm":
            cache["k_pos"] = stage_kpos(B, S, clen)
        caches.append(cache)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = emb.logits_matmul(h[:, -1:], params["out_embed"])[:, 0]
    out = {"stages": caches, "pos": jnp.full((B,), S, jnp.int32)}
    if cfg.enc_dec:
        out["enc_out"] = enc_out
    return _mask_pad_vocab(logits, cfg.vocab), out


def decode_step(params, cfg: ArchConfig, ctx: ModelContext, token, cache):
    """token: (B, 1) int32; cache from prefill/build_cache.
    Returns (logits (B, V), new cache)."""
    pos = cache["pos"]
    h = _embed_in(params, cfg, token, ctx)
    enc_out = cache.get("enc_out")
    new_stages = []
    for sp, stage, sc in zip(params["stages"], build_stages(cfg),
                             cache["stages"]):
        h, nc = apply_stage_decode(h, sp, stage, cfg, ctx, pos, sc,
                                   enc_out=enc_out)
        new_stages.append(nc)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = emb.logits_matmul(h, params["out_embed"])[:, 0]
    new_cache = {"stages": new_stages, "pos": pos + 1}
    if cfg.enc_dec:
        new_cache["enc_out"] = enc_out
    return _mask_pad_vocab(logits, cfg.vocab), new_cache


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract model inputs for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            specs["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), jnp.int32)}
        if cfg.enc_dec:
            specs["enc_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
        return specs
    # decode: one new token against a seq_len cache
    return {"token": sds((B, 1), jnp.int32)}

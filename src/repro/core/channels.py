"""The paper's message channels as composable JAX ops.

Everything operates on arrays with a leading worker axis ``M``; on one
device that axis is a batch dim (exact M-worker simulation), under ``jit``
with the axis sharded it lowers to real collectives (the worker-axis
transpose IS the all-to-all).  Every channel returns a ``stats`` dict with
the *paper's* message metric, computed exactly:

  msgs_basic     — Pregel vertex-to-vertex messages (network only)
  msgs_combined  — after sender-side combining (distinct (src worker, dst
                   vertex) pairs) — Ch_msg with combiner
  msgs_mirror    — Ch_mir: one message per (active mirrored vertex, remote
                   worker hosting a mirror)  [Theorem 1]
  msgs_rr        — request-respond: 2 * distinct (worker, target) pairs
                   [Theorem 3]
  per_worker_*   — (M,) sent-message counts for the Fig.1/2 balance plots
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.core.plan import feat_mask, feat_shape, identity_of, scatter_op
from repro.graph.structs import PartitionedGraph

BACKENDS = ("dense", "pallas")
RELAYS = ("none", "add_w", "mul_w")


def relay_values(src_val: jnp.ndarray, ew, relay: str, lane_ndim: int
                 ) -> jnp.ndarray:
    """Fold the per-edge field into the transported value: the paper's
    relay() hook.  ``add_w`` adds the edge weight (SSSP); ``mul_w``
    multiplies by it (weighted gSpMM: ``u_mul_e``).  The edge weight
    broadcasts over an optional trailing feature axis."""
    if relay == "none":
        return src_val
    if relay not in RELAYS:
        raise ValueError(f"unknown relay {relay!r}; use one of {RELAYS}")
    w = ew if src_val.ndim == lane_ndim else ew[..., None]
    return src_val + w if relay == "add_w" else src_val * w


def _sharded(pg) -> bool:
    """True when ``pg`` is the device-local ShardedGraph inside the
    sharded executor's ``shard_map`` body (core/exec.py): the pg-level
    channels then route to the collective implementations."""
    return getattr(pg, "axis", None) is not None


def _reduce_op(op: str, x: jnp.ndarray, axis: int) -> jnp.ndarray:
    return {"min": jnp.min, "max": jnp.max, "sum": jnp.sum}[op](x, axis=axis)


def _flat_worker(pg, kind: str):
    """(per-edge worker ids, shard->logical map | None) for one flat csr
    edge set.  Under a split partition the ids are *physical shard* ids —
    the granularity at which sender-side combining and request dedup
    physically happen — and the map folds them back to logical workers for
    crossness tests and ``per_worker_*`` reports."""
    if getattr(pg, "phys_log", None) is not None:
        return getattr(pg, f"{kind}_pw"), pg.phys_log
    src = pg.eg_src if kind == "eg" else pg.all_src
    return src // pg.n_loc, None


# ---------------------------------------------------------------------------
# Ch_msg: combined push (sender-side combining + all-to-all)
# ---------------------------------------------------------------------------

def push_combined(targets: jnp.ndarray, values: jnp.ndarray,
                  mask: jnp.ndarray, op: str, M: int, n_loc: int,
                  backend: str = "dense",
                  plan: Optional["planlib.EdgePlan"] = None
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """targets: (M, K) global dst ids; values: (M, K); mask: (M, K).

    Returns (inbox (M, n_loc) combined with ``op``, stats).

    backend="dense": the per-source partial buffer is the paper's combiner;
    its non-identity entries are the combined message count, and the
    worker-axis transpose is the batched send — O(M * n_pad) memory.

    backend="pallas": the combine runs destination-blocked through the
    segment_combine kernel path.  With a precomputed ``plan`` (static
    targets) the packed-row layout feeds ``segment_combine_blocks``;
    without one (runtime targets) the sorted segmented combine is used.
    Either way the O(M * n_pad) partial never materializes and the stats
    are identical to the dense path.
    """
    raw_cross = mask & ((targets // n_loc) != jnp.arange(M)[:, None])
    base = {"msgs_basic": raw_cross.sum(),
            "per_worker_basic": raw_cross.sum(axis=1)}

    feat = feat_shape(values, 2)
    if backend == "pallas":
        if plan is not None:
            # the plan encodes the static edge mask; the runtime mask
            # (e.g. inactive sources) is folded in as identity values
            # for the combine and passed as-is for the accounting
            masked = jnp.where(feat_mask(mask, values, 2), values,
                               identity_of(op, values.dtype))
            inbox, (msgs, per_worker) = planlib.combine_with_plan(
                plan, masked.reshape((-1,) + feat), op, count_cross=True,
                flat_hits=mask.reshape(-1))
        else:
            inbox, (msgs, per_worker) = planlib.combine_sorted(
                targets, values, mask, op, M, n_loc)
        stats = {"msgs_combined": msgs, "per_worker_combined": per_worker}
        stats.update(base)
        return inbox, stats
    if backend != "dense":
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{BACKENDS}")

    ident = identity_of(op, values.dtype)
    n_pad = M * n_loc

    def one(tgt, val, msk):
        v = jnp.where(feat_mask(msk, val, 1), val, ident)
        t = jnp.where(msk, tgt, 0)
        buf = jnp.full((n_pad,) + feat, ident, values.dtype)
        return scatter_op(op, buf, t, v)

    partial = jax.vmap(one)(targets, values, mask)      # (M_src, n_pad, *F)
    partial3 = partial.reshape((M, M, n_loc) + feat)    # (src, dst, slot)

    # mask-driven accounting: a (source, destination) pair counts when a
    # real message was sent, independent of the combined payload
    sent = jax.vmap(lambda t, m: planlib.scatter_hits(n_pad, t, m)
                    )(targets, mask).reshape(M, M, n_loc)
    cross = sent & ~jnp.eye(M, dtype=bool)[:, :, None]
    stats = {
        "msgs_combined": cross.sum(),
        "per_worker_combined": cross.sum(axis=(1, 2)),
    }
    stats.update(base)
    recv = jnp.swapaxes(partial3, 0, 1)                 # the all-to-all
    inbox = _reduce_op(op, recv, axis=1)                # receiver combine
    return inbox, stats


def push_combined_flat(targets: jnp.ndarray, values: jnp.ndarray,
                       mask: jnp.ndarray, src_worker: jnp.ndarray,
                       op: str, M: int, n_loc: int,
                       backend: str = "dense",
                       plan: Optional["planlib.EdgePlan"] = None,
                       log_of: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """CSR-layout twin of ``push_combined``: flat (E,) per-edge arrays with
    explicit per-edge source workers instead of the padded (M, K) rows.

    backend="dense" materializes the same (M_src, n_pad) partial as the
    padded reference via one flat scatter (indices ``w * n_pad + dst`` are
    the flattened per-worker buffers), so inboxes and stats are identical.
    backend="pallas" goes through the precomputed plan (static targets) or
    the flat sorted segmented combine (runtime targets) — the O(M * n_pad)
    partial never materializes.

    Under a split partition ``src_worker`` holds physical shard ids —
    sender-side combining runs per shard, exactly like a physically split
    worker's own combiner — and ``log_of`` ((M_src,) shard -> logical map)
    keeps crossness and the (M,) ``per_worker_*`` report logical.
    """
    wlog = src_worker if log_of is None else jnp.asarray(log_of)[src_worker]
    cross = mask & ((targets // n_loc) != wlog)
    base = {"msgs_basic": cross.sum(),
            "per_worker_basic": jnp.zeros((M,), jnp.int32).at[
                wlog].add(cross.astype(jnp.int32))}

    feat = feat_shape(values, 1)
    if backend == "pallas":
        if plan is not None:
            masked = jnp.where(feat_mask(mask, values, 1), values,
                               identity_of(op, values.dtype))
            inbox, (msgs, per_worker) = planlib.combine_with_plan(
                plan, masked, op, count_cross=True, log_of=log_of,
                M_out=M, flat_hits=mask)
        else:
            inbox, (msgs, per_worker) = planlib.combine_sorted_flat(
                targets, values, mask, src_worker, op, M, n_loc,
                log_of=log_of)
        stats = {"msgs_combined": msgs, "per_worker_combined": per_worker}
        stats.update(base)
        return inbox, stats
    if backend != "dense":
        raise ValueError(f"unknown backend {backend!r}; use one of "
                         f"{BACKENDS}")

    ident = identity_of(op, values.dtype)
    n_pad = M * n_loc
    M_src = M if log_of is None else len(log_of)
    row_log = (jnp.arange(M, dtype=jnp.int32) if log_of is None
               else jnp.asarray(log_of, jnp.int32))
    idx = src_worker * n_pad + jnp.where(mask, targets, 0)
    v = jnp.where(feat_mask(mask, values, 1), values, ident)
    partial = jnp.full((M_src * n_pad,) + feat, ident, values.dtype)
    partial3 = scatter_op(op, partial, idx, v).reshape(
        (M_src, M, n_loc) + feat)

    sent = planlib.scatter_hits(M_src * n_pad, idx, mask
                                ).reshape(M_src, M, n_loc)
    cross3 = sent & (jnp.arange(M)[None, :, None] != row_log[:, None, None])
    stats = {
        "msgs_combined": cross3.sum(),
        "per_worker_combined": jnp.zeros((M,), jnp.int32).at[row_log].add(
            cross3.sum(axis=(1, 2)).astype(jnp.int32)),
    }
    stats.update(base)
    recv = jnp.swapaxes(partial3, 0, 1)                 # the all-to-all
    inbox = _reduce_op(op, recv, axis=1)                # receiver combine
    return inbox, stats


# ---------------------------------------------------------------------------
# Ch_mir: mirror broadcast + local fan-out (with relay() for edge fields)
# ---------------------------------------------------------------------------

def push_mirror(pg: PartitionedGraph, vals: jnp.ndarray, active: jnp.ndarray,
                op: str, relay: str = "none", backend: str = "dense"
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Broadcast each active mirrored vertex's value to its mirrors, fan out
    locally.  vals: (M, n_loc) or feature-blocked (M, n_loc, F);
    active: (M, n_loc).  relay='add_w' adds the edge weight at the mirror
    (the paper's relay() for SSSP); relay='mul_w' multiplies by it
    (weighted gSpMM aggregation)."""
    ident = identity_of(op, vals.dtype)
    n_pad = pg.n_pad
    feat = feat_shape(vals, 2)
    flat_vals = vals.reshape((-1,) + feat)
    flat_act = active.reshape(-1)
    safe = jnp.clip(pg.mir_ids, 0, n_pad - 1)
    valid = pg.mir_ids < n_pad
    mir_act = valid & flat_act[safe]
    mir_vals = jnp.where(feat_mask(mir_act, flat_vals, 1),
                         flat_vals[safe], ident)
    # ^ one value per mirrored vertex: the all-gather payload (Ch_mir send)

    raw = mir_vals[pg.mir_esrc]
    ev = relay_values(raw, pg.mir_ew, relay, pg.mir_esrc.ndim)
    if feat:
        # vector payloads carry the per-lane activity flag explicitly (a
        # feature-wise value==identity test would mask real features)
        ev = jnp.where((pg.mir_emask & mir_act[pg.mir_esrc])[..., None],
                       ev, ident)
    else:
        ev = jnp.where(pg.mir_emask & (raw != ident), ev, ident)
    if backend == "pallas":
        inbox, _ = planlib.combine_with_plan(
            planlib.get_plan(pg, "mir"), ev.reshape((-1,) + feat), op,
            count_cross=False)
    elif pg.layout == "csr":
        # mir_edst is global in csr: per-worker fan-out buffers are
        # disjoint slices of one flat (n_pad,) scatter
        buf = jnp.full((n_pad,) + feat, ident, vals.dtype)
        inbox = scatter_op(op, buf, pg.mir_edst, ev).reshape(
            (pg.M, pg.n_loc) + feat)
    else:
        def fan_out(edst, emask, ev_row):
            buf = jnp.full((pg.n_loc,) + feat, ident, vals.dtype)
            return scatter_op(op, buf, jnp.where(emask, edst, 0), ev_row)

        inbox = jax.vmap(fan_out)(pg.mir_edst, pg.mir_emask, ev)
    # mask-driven accounting: an ACTIVE mirrored vertex is broadcast to its
    # hosting workers whatever its value (even one equal to the identity)
    sent = jnp.where(valid & flat_act[safe], pg.mir_nworkers, 0)
    owner_w = jnp.clip(safe // pg.n_loc, 0, pg.M - 1)
    per_worker = jnp.zeros((pg.M,), sent.dtype).at[owner_w].add(
        jnp.where(valid, sent, 0))
    stats = {"msgs_mirror": sent.sum(), "per_worker_mirror": per_worker}
    return inbox, stats


def broadcast(pg: PartitionedGraph, vals: jnp.ndarray, active: jnp.ndarray,
              op: str, relay: str = "none", use_mirroring: bool = True,
              backend: str = "dense"
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """The full paper pipeline: low-degree vertices push through Ch_msg with
    combining; high-degree (>= pg.tau) vertices through Ch_mir.  ``vals`` is
    each vertex's broadcast value (a(v)); relay folds edge fields.
    use_mirroring=False routes EVERY edge through Ch_msg (Pregel-noM).
    backend="pallas" drives both channels through the precomputed message
    plans (destination-blocked segment_combine) instead of dense scatters;
    inboxes and message stats are unchanged.  ``pg.layout`` picks the edge
    representation (padded rows vs flat csr) — results and stats are
    layout-invariant.  Inside the sharded executor ``pg`` is the
    device-local ShardedGraph and the same call lowers to real collectives
    (all_to_all / op-matched all-reduce) with identical stats."""
    if _sharded(pg):
        from repro.core import exec as exec_mod
        return exec_mod.broadcast_sharded(pg, vals, active, op, relay,
                                          use_mirroring, backend)
    esrc = pg.eg_src if use_mirroring else pg.all_src
    edst = pg.eg_dst if use_mirroring else pg.all_dst
    emask = pg.eg_mask if use_mirroring else pg.all_mask
    ew = pg.eg_w if use_mirroring else pg.all_w
    feat = feat_shape(vals, 2)
    plan = (planlib.get_plan(pg, "eg" if use_mirroring else "all")
            if backend == "pallas" else None)
    if pg.layout == "csr":
        src_val = vals.reshape((-1,) + feat)[esrc]  # esrc is global in csr
        src_act = active.reshape(-1)[esrc]
        v = relay_values(src_val, ew, relay, 1)
        worker, log_of = _flat_worker(pg, "eg" if use_mirroring else "all")
        inbox, stats = push_combined_flat(edst, v, emask & src_act,
                                          worker, op,
                                          pg.M, pg.n_loc, backend=backend,
                                          plan=plan, log_of=log_of)
    else:
        src_val = vals[jnp.arange(pg.M)[:, None], esrc]
        src_act = active[jnp.arange(pg.M)[:, None], esrc]
        v = relay_values(src_val, ew, relay, 2)
        inbox, stats = push_combined(edst, v, emask & src_act, op,
                                     pg.M, pg.n_loc, backend=backend,
                                     plan=plan)
    if use_mirroring:
        inbox2, s2 = push_mirror(pg, vals, active, op, relay,
                                 backend=backend)
        inbox = {"min": jnp.minimum, "max": jnp.maximum,
                 "sum": jnp.add}[op](inbox, inbox2)
        stats.update(s2)
    else:
        stats["msgs_mirror"] = jnp.zeros((), jnp.int32)
        stats["per_worker_mirror"] = jnp.zeros((pg.M,), jnp.int32)
    stats["msgs_total"] = stats["msgs_combined"] + stats["msgs_mirror"]
    stats["per_worker_total"] = (stats["per_worker_combined"]
                                 + stats["per_worker_mirror"])
    return inbox, stats


# ---------------------------------------------------------------------------
# Ch_req: request-respond distributed gather  (paper §6)
# ---------------------------------------------------------------------------

def _dedup_row(t: jnp.ndarray, sentinel: int):
    """Sort-based dedup of one worker's request list (static shapes)."""
    R = t.shape[0]
    order = jnp.argsort(t)
    s = t[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    first &= s < sentinel
    rank = jnp.cumsum(first) - 1
    uniq = jnp.full((R,), -1, t.dtype).at[jnp.where(first, rank, R - 1)
                                          ].max(jnp.where(first, s, -1))
    uniq = jnp.where(uniq < 0, sentinel, uniq)
    inv = jnp.zeros((R,), jnp.int32).at[order].set(rank.astype(jnp.int32))
    return uniq, inv


def rr_gather(vals: jnp.ndarray, targets: jnp.ndarray, tmask: jnp.ndarray,
              M: int, n_loc: int, dedup: bool = True
              ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Distributed gather: each worker reads vals[target] for arbitrary
    global targets (the paper's request(u) / get_resp(u)).

    vals: (M, n_loc); targets/tmask: (M, R).  Returns (out (M, R), stats).
    dedup=True is the request-respond channel (one request per distinct
    target per worker — Theorem 3); dedup=False sends every request
    individually (Pregel basic: msgs_rr degenerates to msgs_basic), same
    gathered values either way.
    """
    n_pad = M * n_loc
    R = targets.shape[1]
    feat = feat_shape(vals, 2)
    t = jnp.where(tmask, targets, n_pad)

    if dedup:
        uniq, inv = jax.vmap(lambda r: _dedup_row(r, n_pad))(t)  # (M,R) x2
    else:
        uniq = t
        inv = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32), (M, R))
    owner = jnp.clip(uniq // n_loc, 0, M - 1)
    uvalid = uniq < n_pad

    # bucket requests by owner: reqbuf[src, owner, cap]
    cap = R

    def bucketize(u_row, ow_row, val_row):
        onehot = (ow_row[None, :] == jnp.arange(M)[:, None]) & val_row[None, :]
        pos = jnp.cumsum(onehot, axis=1) - onehot.astype(jnp.int32)
        pos_of = (pos * onehot).sum(0)
        dest = jnp.where(val_row, ow_row * cap + pos_of, M * cap)
        buf = jnp.full((M * cap + 1,), n_pad, jnp.int32
                       ).at[dest].set(u_row.astype(jnp.int32))
        return buf[:-1].reshape(M, cap), pos_of

    reqbuf, pos_of = jax.vmap(bucketize)(uniq, owner, uvalid)
    recv = jnp.swapaxes(reqbuf, 0, 1)                  # (owner, src, cap)

    def respond(vals_row, rec_row, w):
        slot = rec_row - w * n_loc
        ok = (slot >= 0) & (slot < n_loc)
        got = vals_row[jnp.clip(slot, 0, n_loc - 1)]   # (src, cap, *F)
        return jnp.where(feat_mask(ok, got, 2), got,
                         jnp.zeros((), vals.dtype))

    resp = jax.vmap(respond)(vals, recv, jnp.arange(M))  # (owner, src, cap)
    back = jnp.swapaxes(resp, 0, 1)                      # (src, owner, cap)

    def collect(back_row, ow_row, pos_row, inv_row, uvalid_row):
        uniq_vals = back_row.reshape((-1,) + feat)[ow_row * cap + pos_row]
        uniq_vals = jnp.where(feat_mask(uvalid_row, uniq_vals, 1),
                              uniq_vals, 0)
        return uniq_vals[inv_row]

    out = jax.vmap(collect)(back, owner, pos_of, inv, uvalid)
    out = jnp.where(feat_mask(tmask, out, 2), out, 0)

    self_w = jnp.arange(M)[:, None]
    remote_u = uvalid & (owner != self_w)
    raw_remote = tmask & ((targets // n_loc) != self_w)
    n_rr = remote_u.sum()
    n_basic = raw_remote.sum()
    stats = {
        "msgs_rr": 2 * n_rr,
        "msgs_basic": 2 * n_basic,
        "per_worker_rr": remote_u.sum(1) + jnp.zeros((M,), jnp.int32
                                                     ).at[jnp.where(remote_u, owner, 0).reshape(-1)
                                                          ].add(remote_u.reshape(-1).astype(jnp.int32)),
        "per_worker_basic": raw_remote.sum(1)
        + jnp.zeros((M,), jnp.int32).at[
            jnp.where(raw_remote, jnp.clip(targets // n_loc, 0, M - 1), 0
                      ).reshape(-1)].add(raw_remote.reshape(-1).astype(jnp.int32)),
    }
    return out, stats


def rr_gather_flat(vals: jnp.ndarray, targets: jnp.ndarray,
                   worker: jnp.ndarray, tmask: jnp.ndarray,
                   M: int, n_loc: int, dedup: bool = True,
                   log_of: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """CSR-layout twin of ``rr_gather``: flat (E,) targets with explicit
    (E,) requesting-worker ids (ragged per-worker request counts).

    The gathered values are a direct read; the stats reproduce the padded
    channel's accounting exactly — msgs_rr counts 2 messages per distinct
    remote (worker, target) pair (Theorem 3), per_worker_* charge both the
    requester and the owner, msgs_basic counts every raw remote request.

    Under a split partition ``worker`` holds physical shard ids (each
    shard deduplicates its own request list) and ``log_of`` maps them back
    to logical workers for the remote test and the per-worker charges.
    """
    n_pad = M * n_loc
    E = targets.shape[0]
    feat = feat_shape(vals, 2)
    t = jnp.where(tmask, targets, n_pad)
    got = vals.reshape((-1,) + feat)[jnp.clip(t, 0, n_pad - 1)]
    out = jnp.where(feat_mask(tmask, got, 1), got,
                    jnp.zeros((), vals.dtype))
    zero_m = jnp.zeros((M,), jnp.int32)
    if E == 0:
        stats = {"msgs_rr": jnp.zeros((), jnp.int32),
                 "msgs_basic": jnp.zeros((), jnp.int32),
                 "per_worker_rr": zero_m, "per_worker_basic": zero_m}
        return out, stats

    wlog = worker if log_of is None else jnp.asarray(log_of)[worker]
    owner = jnp.clip(targets // n_loc, 0, M - 1)
    raw_remote = tmask & ((targets // n_loc) != wlog)
    if dedup:
        # distinct (worker, target) = segment heads of the shared sort
        _, ws, ts, first = planlib.sort_by_worker_target(worker, t)
        ws_log = ws if log_of is None else jnp.asarray(log_of)[ws]
        uniq = first & (ts < n_pad)
        remote_u = uniq & (ts // n_loc != ws_log)
        u_w, u_owner = ws_log, jnp.clip(ts // n_loc, 0, M - 1)
    else:
        remote_u = raw_remote
        u_w, u_owner = wlog, owner
    n_rr = remote_u.sum()
    n_basic = raw_remote.sum()
    r32 = remote_u.astype(jnp.int32)
    b32 = raw_remote.astype(jnp.int32)
    stats = {
        "msgs_rr": 2 * n_rr,
        "msgs_basic": 2 * n_basic,
        "per_worker_rr": (zero_m.at[jnp.where(remote_u, u_w, 0)].add(r32)
                          + zero_m.at[jnp.where(remote_u, u_owner, 0)
                                      ].add(r32)),
        "per_worker_basic": (zero_m.at[jnp.where(raw_remote, wlog, 0)
                                       ].add(b32)
                             + zero_m.at[jnp.where(raw_remote, owner, 0)
                                         ].add(b32)),
    }
    return out, stats


def scatter_combine(vals: jnp.ndarray, targets: jnp.ndarray,
                    upd: jnp.ndarray, mask: jnp.ndarray, op: str,
                    M: int, n_loc: int, backend: str = "dense"):
    """Distributed scatter-``op`` into vals (S-V hooking writes).  Messages
    are counted like the combined channel (one per distinct (worker, target)
    after sender-side combining).  Targets are runtime state, so
    backend="pallas" uses the sorted segmented combine (no precomputed
    plan is possible) — same stats, O(n_pad) instead of O(M * n_pad)."""
    inbox, stats = push_combined(targets, upd, mask, op, M, n_loc,
                                 backend=backend)
    fn = {"min": jnp.minimum, "max": jnp.maximum, "sum": jnp.add}[op]
    return fn(vals, inbox), stats


def scatter_combine_flat(vals: jnp.ndarray, targets: jnp.ndarray,
                         upd: jnp.ndarray, mask: jnp.ndarray,
                         worker: jnp.ndarray, op: str,
                         M: int, n_loc: int, backend: str = "dense",
                         log_of: Optional[jnp.ndarray] = None):
    """CSR twin of ``scatter_combine``: flat (E,) edge-shaped writes with
    explicit per-edge source workers (MSF min-edge election)."""
    inbox, stats = push_combined_flat(targets, upd, mask, worker, op,
                                      M, n_loc, backend=backend,
                                      log_of=log_of)
    fn = {"min": jnp.minimum, "max": jnp.maximum, "sum": jnp.add}[op]
    return fn(vals, inbox), stats


# ---------------------------------------------------------------------------
# pg-level wrappers: layout- and sharding-dispatching channel entry points
# ---------------------------------------------------------------------------

def gather(pg, vals: jnp.ndarray, targets: jnp.ndarray, tmask: jnp.ndarray,
           dedup: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """Distributed pointer read ``vals[target]`` for state-shaped target
    rows (S-V / MSF pointer chasing).  Dispatches to the sharded Ch_req
    under the executor."""
    if _sharded(pg):
        from repro.core import exec as exec_mod
        return exec_mod.gather_sharded(pg, vals, targets, tmask, dedup)
    return rr_gather(vals, targets, tmask, pg.M, pg.n_loc, dedup)


def gather_edges(pg, vals: jnp.ndarray, targets: jnp.ndarray,
                 tmask: jnp.ndarray, dedup: bool = True
                 ) -> Tuple[jnp.ndarray, Dict]:
    """Distributed gather for edge-shaped targets aligned with the ``all``
    adjacency (attribute broadcast, MSF neighbor reads): padded rows go
    through ``rr_gather``, flat csr through ``rr_gather_flat`` with the
    per-edge source worker derived from ``pg.all_src``."""
    if _sharded(pg):
        from repro.core import exec as exec_mod
        return exec_mod.gather_edges_sharded(pg, vals, targets, tmask,
                                             dedup)
    if pg.layout == "csr":
        worker, log_of = _flat_worker(pg, "all")
        return rr_gather_flat(vals, targets, worker, tmask,
                              pg.M, pg.n_loc, dedup, log_of=log_of)
    return rr_gather(vals, targets, tmask, pg.M, pg.n_loc, dedup)


def scatter_state(pg, base: jnp.ndarray, targets: jnp.ndarray,
                  upd: jnp.ndarray, mask: jnp.ndarray, op: str,
                  backend: str = "dense") -> Tuple[jnp.ndarray, Dict]:
    """Distributed scatter-``op`` for state-shaped runtime targets (S-V
    hooking writes)."""
    if _sharded(pg):
        from repro.core import exec as exec_mod
        return exec_mod.scatter_state_sharded(pg, base, targets, upd, mask,
                                              op, backend)
    return scatter_combine(base, targets, upd, mask, op, pg.M, pg.n_loc,
                           backend=backend)


def scatter_edges(pg, base: jnp.ndarray, targets: jnp.ndarray,
                  upd: jnp.ndarray, mask: jnp.ndarray, op: str,
                  backend: str = "dense") -> Tuple[jnp.ndarray, Dict]:
    """Distributed scatter-``op`` for edge-shaped runtime values aligned
    with the ``all`` adjacency (MSF min-edge election)."""
    if _sharded(pg):
        from repro.core import exec as exec_mod
        return exec_mod.scatter_edges_sharded(pg, base, targets, upd, mask,
                                              op, backend)
    if pg.layout == "csr":
        worker, log_of = _flat_worker(pg, "all")
        return scatter_combine_flat(base, targets, upd, mask,
                                    worker, op,
                                    pg.M, pg.n_loc, backend=backend,
                                    log_of=log_of)
    return scatter_combine(base, targets, upd, mask, op, pg.M, pg.n_loc,
                           backend=backend)

"""Message plans: destination-blocked layouts for the combine channels.

The dense Ch_msg path materializes a per-source-worker partial buffer of
shape (M, n_pad) — O(M^2 * n_loc) memory per superstep, which caps the
graph sizes one host can simulate.  A *message plan* is built once per
partitioned graph: every worker's outgoing edges are grouped by
(source worker, destination block) into fixed-width rows, generalizing
``pack_edges``/``pack_values`` (kernels/segment_combine/ops.py) to the
leading (M, ...) worker axis with fully vectorized numpy (no per-block
Python loops).  At superstep time the runtime gathers the per-edge values
into the packed layout and hands rows to ``segment_combine_blocks`` — the
purpose-built Pallas kernel — so the combine works block-by-block in VMEM
and the only O(n) buffers are the packed edges and the (n_blocks, nb)
output.

Blocking scheme: destination worker ``w`` owns local slots [0, n_loc);
block ``b`` of ``w`` covers local slots [b*nb, (b+1)*nb).  Global block id
= w * B_per_w + b, so a block never spans two workers and per-(source,
block) non-identity counts reproduce the paper's combined-message metric
exactly (distinct (source worker, destination vertex) pairs).

Oversized groups are split across multiple rows of the same segment; the
rows are merged with the combine op before counting, so splitting never
double-counts a destination.

Two runtime paths:

* ``combine_with_plan`` — static targets (the broadcast/mirror channels,
  whose edges are known at partition time): packed rows -> kernel ->
  segment merge -> global block scatter.
* ``combine_sorted``   — dynamic targets (S-V / MSF hooking writes, whose
  destinations are algorithm state): per-row sort + segmented reduce +
  one flat (n_pad,) scatter.  Same O(n_pad + M*K) memory bound, no
  precomputation possible.

Kernel dispatch: the Pallas kernel is compiled for real on TPU; on CPU the
block-layout jnp reference (same math, same layout) executes the plan, and
``set_kernel_mode('pallas')`` forces interpret-mode Pallas for wiring
tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_combine.kernel import (NEG, POS, sentinels,
                                                  segment_combine_blocks)
from repro.kernels.segment_combine.ref import segment_combine_blocks_ref

DEFAULT_NB = 128
DEFAULT_EB = 128


def default_nb() -> int:
    """Destination-block width: 128 on TPU (the lane width the kernel's
    hit-matrix wants); 32 on CPU, where narrower blocks shrink the
    (n_rows, nb) combined-block temp 2-3x with no layout downside."""
    return DEFAULT_NB if jax.default_backend() == "tpu" else 32

# "auto": Pallas kernel on TPU, block-layout jnp reference elsewhere.
# "pallas": force the kernel (interpret mode off-TPU). "ref": force jnp.
_KERNEL_MODE = "auto"


def set_kernel_mode(mode: str) -> None:
    global _KERNEL_MODE
    assert mode in ("auto", "pallas", "ref"), mode
    _KERNEL_MODE = mode


def kernel_mode() -> str:
    return _KERNEL_MODE


def identity_of(op: str, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.asarray({"min": info.max, "max": info.min, "sum": 0}[op],
                           dtype)
    return jnp.asarray({"min": jnp.inf, "max": -jnp.inf, "sum": 0.0}[op],
                       dtype)


def scatter_op(op: str, buf, idx, vals):
    if op == "min":
        return buf.at[idx].min(vals)
    if op == "max":
        return buf.at[idx].max(vals)
    return buf.at[idx].add(vals)


def feat_mask(mask, values, lane_ndim: int):
    """Broadcast a lane mask over an optional trailing feature axis.

    The vector-payload convention everywhere: a value array is either
    lane-shaped (``lane_ndim`` axes, one value per lane — today's scalar
    contract, untouched) or carries ONE extra trailing feature axis
    ``(..., F)``.  Scalar inputs return ``mask`` unchanged, so the F=1
    bitwise-identity guarantee is structural, not numerical."""
    return mask if values.ndim == lane_ndim else mask[..., None]


def feat_shape(values, lane_ndim: int) -> tuple:
    """() for scalar payloads, (F,) for feature-blocked ones."""
    return tuple(values.shape[lane_ndim:])


def scatter_hits(n: int, idx, hits) -> jnp.ndarray:
    """(n,) bool "did at least one real message land here" from per-lane
    ``hits`` flags — the honest (mask-driven) message-accounting primitive:
    a destination counts when a real message was SENT to it, whatever its
    payload (a PageRank contribution of exactly 0.0 is still a message).
    ``idx`` lanes with ``hits`` False may point anywhere in range."""
    buf = jnp.zeros((n,), jnp.int32)
    return buf.at[jnp.where(hits, idx, 0)].max(hits.astype(jnp.int32)) > 0


@dataclasses.dataclass
class EdgePlan:
    """Packed destination-blocked layout of one edge set.

    Rows are (eb,)-wide slices of one (source worker, destination block)
    segment; ``row_gather`` indexes the *flattened* (M_src * E,) per-edge
    value array.
    """
    M_src: int
    M_dst: int
    n_loc: int
    nb: int
    eb: int
    B_per_w: int               # destination blocks per worker
    n_blocks: int              # M_dst * B_per_w
    n_segs: int
    n_rows: int
    # host-side numpy (NOT jnp): plans are built lazily, possibly while a
    # jit trace is active, and get closed over by many traced steps —
    # numpy constants are safe to reuse across traces, tracers are not.
    row_gather: np.ndarray     # (n_rows, eb) int32 -> flat edge index
    row_valid: np.ndarray      # (n_rows, eb) bool
    row_local: np.ndarray      # (n_rows, eb) int32 dst-in-block, pad -1
    row_seg: np.ndarray        # (n_rows,) int32 -> segment
    seg_blk: np.ndarray        # (n_segs,) int32 global block id
    seg_worker: np.ndarray     # (n_segs,) int32 source worker

    @property
    def packed_bytes(self) -> int:
        return self.n_rows * self.eb * 9 + self.n_rows * 4


def build_edge_plan(dst_worker: np.ndarray, dst_local: np.ndarray,
                    mask: np.ndarray, M_dst: int, n_loc: int,
                    nb: int = DEFAULT_NB,
                    eb: Optional[int] = None) -> EdgePlan:
    """dst_worker/dst_local/mask: (M_src, E) host arrays (padded layout).
    Vectorized: one argsort over the kept edges, no per-block loops.

    ``eb`` (row width) defaults to adapting to the segment-size
    distribution: the p90 segment size rounded up to a power of two in
    [8, DEFAULT_EB*4].  Narrow rows keep padding low on sparse segments
    (many workers, few edges per block); oversized segments simply span
    multiple rows, which the segment merge re-combines.  8 is the f32
    sublane minimum, so every choice stays TPU-tileable."""
    dst_worker = np.asarray(dst_worker)
    dst_local = np.asarray(dst_local)
    mask = np.asarray(mask)
    M_src, E = dst_worker.shape

    keep = mask.reshape(-1)
    flat_idx = np.flatnonzero(keep).astype(np.int64)
    src_w = flat_idx // max(E, 1)
    return _pack_edge_plan(flat_idx, src_w,
                           dst_worker.reshape(-1)[flat_idx],
                           dst_local.reshape(-1)[flat_idx],
                           M_src, M_dst, n_loc, nb, eb)


def build_edge_plan_flat(src_worker: np.ndarray, dst_worker: np.ndarray,
                         dst_local: np.ndarray, M_src: int, M_dst: int,
                         n_loc: int, nb: int = DEFAULT_NB,
                         eb: Optional[int] = None) -> EdgePlan:
    """CSR-layout twin of ``build_edge_plan``: flat (E,) edge arrays with
    explicit per-edge source workers, no padding mask.  ``row_gather``
    then indexes the flat (E,) per-edge value array directly — the CSR
    layout is destination-blockable without an intermediate padded
    unpack."""
    src_worker = np.asarray(src_worker, np.int64)
    flat_idx = np.arange(len(src_worker), dtype=np.int64)
    return _pack_edge_plan(flat_idx, src_worker,
                           np.asarray(dst_worker, np.int64),
                           np.asarray(dst_local, np.int64),
                           M_src, M_dst, n_loc, nb, eb)


def _pack_edge_plan(flat_idx: np.ndarray, src_w: np.ndarray,
                    dst_worker: np.ndarray, dst_local: np.ndarray,
                    M_src: int, M_dst: int, n_loc: int, nb: int,
                    eb: Optional[int]) -> EdgePlan:
    """Shared packer: per-kept-edge flat value index + (source worker,
    destination worker/local) -> destination-blocked rows."""
    B_per_w = max(-(-n_loc // nb), 1)
    n_blocks = M_dst * B_per_w
    blk = dst_worker * B_per_w + dst_local // nb
    loc_in_blk = dst_local % nb

    key = src_w * n_blocks + blk
    order = np.argsort(key, kind="stable")
    skey = key[order]
    n_kept = len(skey)

    if n_kept == 0:
        eb = eb or DEFAULT_EB
        return EdgePlan(M_src, M_dst, n_loc, nb, eb, B_per_w, n_blocks,
                        0, 0, np.zeros((0, eb), np.int32),
                        np.zeros((0, eb), bool),
                        np.zeros((0, eb), np.int32),
                        np.zeros((0,), np.int32),
                        np.zeros((0,), np.int32),
                        np.zeros((0,), np.int32))

    first = np.concatenate([[True], skey[1:] != skey[:-1]])
    seg_of = np.cumsum(first) - 1                       # per kept edge
    n_segs = int(seg_of[-1]) + 1
    seg_key = skey[first]
    seg_start = np.flatnonzero(first)
    seg_count = np.diff(np.append(seg_start, n_kept))
    pos = np.arange(n_kept) - seg_start[seg_of]         # rank within segment

    if eb is None:
        p90 = int(np.percentile(seg_count, 90))
        eb = 8
        while eb < p90 and eb < DEFAULT_EB * 4:
            eb *= 2

    seg_nrows = -(-seg_count // eb)
    seg_row0 = np.concatenate([[0], np.cumsum(seg_nrows)[:-1]])
    n_rows = int(seg_nrows.sum())
    row_of = seg_row0[seg_of] + pos // eb
    col_of = pos % eb

    row_gather = np.zeros((n_rows, eb), np.int32)
    row_valid = np.zeros((n_rows, eb), bool)
    row_local = np.full((n_rows, eb), -1, np.int32)
    slot = row_of * eb + col_of
    row_gather.reshape(-1)[slot] = flat_idx[order]
    row_valid.reshape(-1)[slot] = True
    row_local.reshape(-1)[slot] = loc_in_blk[order]

    row_seg = np.repeat(np.arange(n_segs, dtype=np.int32),
                        seg_nrows.astype(np.int64))
    return EdgePlan(
        M_src, M_dst, n_loc, nb, eb, B_per_w, n_blocks, n_segs, n_rows,
        row_gather, row_valid, row_local, row_seg,
        (seg_key % n_blocks).astype(np.int32),
        (seg_key // n_blocks).astype(np.int32))


def _combine_rows(packed: jnp.ndarray, row_local: jnp.ndarray, op: str,
                  nb: int) -> jnp.ndarray:
    """Dispatch one (n_rows, eb) -> (n_rows, nb) block combine."""
    mode = _KERNEL_MODE
    if mode == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "ref"
    if mode == "ref":
        out = segment_combine_blocks_ref(packed, row_local, op, nb)
    else:
        out = segment_combine_blocks(
            packed, row_local, op, nb,
            interpret=jax.default_backend() != "tpu")
    # The kernel's float min/max identities are finite sentinels
    # (VMEM-friendly); map no-hit slots back to the channel identities so
    # the combined blocks compare exactly against the dense path.  Integer
    # blocks already use iinfo bounds == the channel identities, so the
    # id-carrying algorithms combine exactly in their integer dtype.
    # The thresholds come from sentinels(dtype): float16 blocks saturate
    # at +-65504, where the canonical 3e38 would overflow to inf and the
    # comparison could never fire.
    if jnp.issubdtype(packed.dtype, jnp.floating):
        neg, pos = sentinels(packed.dtype)
        if op == "min":
            out = jnp.where(out >= pos, jnp.inf, out)
        elif op == "max":
            out = jnp.where(out <= neg, -jnp.inf, out)
    return out


def combine_rows_subset(plan, flat_vals: jnp.ndarray, rows: jnp.ndarray,
                        rows_ok: jnp.ndarray, op: str) -> jnp.ndarray:
    """Combine one static subset of plan rows (a pipeline chunk): gather
    the rows' packed lanes and run the same kernel-dispatched block
    combine as the whole-plan path.  Rows are independent inside
    ``segment_combine_blocks``, so a chunk's blocks combine
    bitwise-identically to their slice of the full-plan combine.

    ``rows_ok`` masks padded chunk slots (their lanes combine to the op
    identity, so scattering them anywhere is harmless for min/max/sum).
    Works on both EdgePlan (host numpy fields) and the executor's
    TracedPlan (device arrays) — only ``row_gather``/``row_valid``/
    ``row_local``/``nb`` are read."""
    ident = identity_of(op, flat_vals.dtype)
    valid = rows_ok[:, None] & jnp.asarray(plan.row_valid)[rows]
    gathered = flat_vals[jnp.asarray(plan.row_gather)[rows]]
    packed = jnp.where(feat_mask(valid, gathered, 2), gathered, ident)
    rloc = jnp.where(valid, jnp.asarray(plan.row_local)[rows], -1)
    return _combine_rows(packed, rloc, op, plan.nb)


def plan_seg_hits(plan: EdgePlan, flat_hits: jnp.ndarray) -> jnp.ndarray:
    """(n_segs, nb) bool: did >= 1 real (masked-in) message land in each
    per-(source, block) destination slot?  The mask-driven twin of the
    value combine — counting by ``combined != identity`` silently drops
    genuine messages whose payload equals the identity.  Rides the same
    block-combine kernel as the values (op=max over 0/1 lanes)."""
    hitp = plan.row_valid & flat_hits[plan.row_gather]       # (n_rows, eb)
    rh = _combine_rows(hitp.astype(jnp.int32), plan.row_local, "max",
                       plan.nb)
    sh = jnp.zeros((plan.n_segs, plan.nb), jnp.int32)
    return sh.at[plan.row_seg].max(rh) > 0


def combine_with_plan(plan: EdgePlan, flat_vals: jnp.ndarray, op: str,
                      count_cross: bool = True,
                      log_of: Optional[np.ndarray] = None,
                      M_out: Optional[int] = None,
                      flat_hits: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Combine per-edge values (flattened (M_src*E,)) into a (M_dst, n_loc)
    inbox.  Returns (inbox, (msgs_combined, per_worker_combined) | None);
    the count is the paper's combined-message metric: distinct (source
    worker, destination vertex) pairs that received at least one real
    message (``flat_hits``, the runtime send mask — identity-valued real
    messages count too), destination owned by another worker.

    Plans built from a *split* partition key their segments by physical
    shard (combining runs per shard); ``log_of`` then maps shard ids back
    to logical workers — a message is cross iff it leaves the *logical*
    worker, and ``per_worker_combined`` is reported over the ``M_out``
    logical workers.
    """
    assert flat_vals.ndim in (1, 2), \
        "pass per-edge values flattened: (E,) or feature-blocked (E, F)"
    feat = feat_shape(flat_vals, 1)
    if plan.n_rows:
        assert int(plan.row_gather.max()) < flat_vals.shape[0], \
            "plan does not match this edge set"
    M_out = M_out if M_out is not None else plan.M_src
    ident = identity_of(op, flat_vals.dtype)
    if plan.n_rows == 0:
        inbox = jnp.full((plan.M_dst, plan.n_loc) + feat, ident,
                         flat_vals.dtype)
        if count_cross:
            return inbox, (jnp.zeros((), jnp.int32),
                           jnp.zeros((M_out,), jnp.int32))
        return inbox, None

    gathered = flat_vals[plan.row_gather]
    packed = jnp.where(feat_mask(plan.row_valid, gathered, 2), gathered,
                       ident)
    row_out = _combine_rows(packed, plan.row_local, op, plan.nb)

    seg_buf = jnp.full((plan.n_segs, plan.nb) + feat, ident,
                       flat_vals.dtype)
    seg_out = scatter_op(op, seg_buf, plan.row_seg, row_out)

    glob = jnp.full((plan.n_blocks, plan.nb) + feat, ident, flat_vals.dtype)
    glob = scatter_op(op, glob, plan.seg_blk, seg_out)
    inbox = glob.reshape((plan.M_dst, plan.B_per_w * plan.nb) + feat
                         )[:, :plan.n_loc]

    stats = None
    if count_cross:
        assert flat_hits is not None, \
            "count_cross=True needs the per-lane send mask (flat_hits)"
        seg_log = (plan.seg_worker if log_of is None
                   else np.asarray(log_of)[plan.seg_worker])
        owner = plan.seg_blk // plan.B_per_w
        cross = plan_seg_hits(plan, flat_hits) & (owner != seg_log)[:, None]
        msgs = cross.sum().astype(jnp.int32)
        per_worker = jnp.zeros((M_out,), jnp.int32).at[
            seg_log].add(cross.sum(axis=1).astype(jnp.int32))
        stats = (msgs, per_worker)
    return inbox, stats


# ---------------------------------------------------------------------------
# dynamic targets: sorted segmented combine (no precomputation possible)
# ---------------------------------------------------------------------------

def sorted_segments(targets: jnp.ndarray, values: jnp.ndarray,
                    mask: jnp.ndarray, op: str, n_pad: int):
    """Per-row sort + segmented reduce of runtime (R, K) target rows:
    the shared core of the sorted combine, used by both the single-device
    path below and the sharded executor (core/exec.py) so the combine and
    message-accounting rules live in exactly one place.

    Returns ``(real, seg_t, seg_val, seg_row, ident)``: for every live
    (row, distinct target) segment its validity, target, combined value,
    and source row."""
    ident = identity_of(op, values.dtype)
    feat = feat_shape(values, 2)
    R, K = targets.shape
    t = jnp.where(mask, targets, n_pad)          # sentinel sorts last
    order = jnp.argsort(t, axis=1)
    ts = jnp.take_along_axis(t, order, axis=1)
    vs = jnp.take_along_axis(
        jnp.where(feat_mask(mask, values, 2), values, ident),
        feat_mask(order, values, 2), axis=1)

    first = jnp.concatenate(
        [jnp.ones((R, 1), bool), ts[:, 1:] != ts[:, :-1]], axis=1)
    seg_id = (jnp.cumsum(first.reshape(-1)) - 1).astype(jnp.int32)
    seg_fn = {"min": jax.ops.segment_min, "max": jax.ops.segment_max,
              "sum": jax.ops.segment_sum}[op]
    seg_val = seg_fn(vs.reshape((R * K,) + feat), seg_id,
                     num_segments=R * K)
    seg_t = jax.ops.segment_min(ts.reshape(-1), seg_id, num_segments=R * K)
    rows = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32)[:, None], (R, K))
    seg_row = jax.ops.segment_min(rows.reshape(-1), seg_id,
                                  num_segments=R * K)
    live = jnp.zeros((R * K,), bool).at[seg_id].set(True)
    real = live & (seg_t < n_pad)
    return real, seg_t, seg_val, seg_row, ident


def combine_sorted(targets: jnp.ndarray, values: jnp.ndarray,
                   mask: jnp.ndarray, op: str, M: int, n_loc: int
                   ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Sender-side combine for runtime target arrays (M, K): sort each
    worker's targets, reduce duplicate targets with ``jax.ops.segment_*``,
    then one flat scatter into a single (n_pad,) buffer — never the dense
    (M, n_pad) partial.  Returns (inbox (M, n_loc), (msgs_combined,
    per_worker_combined)), combined counts identical to the dense path.
    """
    n_pad = M * n_loc
    feat = feat_shape(values, 2)
    real, seg_t, seg_val, seg_row, ident = sorted_segments(
        targets, values, mask, op, n_pad)

    # inbox: receiver applies the same associative op, so one flat scatter
    # of the per-segment combined values is exact.
    buf = jnp.full((n_pad,) + feat, ident, values.dtype)
    buf = scatter_op(op, buf, jnp.where(real, seg_t, 0),
                      jnp.where(feat_mask(real, seg_val, 1), seg_val, ident))
    inbox = buf.reshape((M, n_loc) + feat)

    # mask-driven crossness: a live segment IS >= 1 real message — never
    # test the combined value against the identity (a genuine payload can
    # equal it, e.g. a PageRank contribution of exactly 0.0 under sum)
    cross = real & (seg_t // n_loc != seg_row)
    msgs = cross.sum().astype(jnp.int32)
    per_worker = jnp.zeros((M,), jnp.int32).at[
        jnp.where(cross, seg_row, 0)].add(cross.astype(jnp.int32))
    return inbox, (msgs, per_worker)


def sort_by_worker_target(worker: jnp.ndarray, t: jnp.ndarray):
    """Two-pass stable sort of flat (E,) pairs by (worker, target) — no
    ``worker * n_pad + target`` composite key that could overflow int32.
    Returns (order, sorted worker, sorted target, first-of-segment mask);
    a segment is one distinct (worker, target) pair."""
    order1 = jnp.argsort(t, stable=True)
    order = order1[jnp.argsort(worker[order1], stable=True)]
    ws, ts = worker[order], t[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (ws[1:] != ws[:-1]) | (ts[1:] != ts[:-1])])
    return order, ws, ts, first


def sorted_segments_flat(targets: jnp.ndarray, values: jnp.ndarray,
                         mask: jnp.ndarray, src_worker: jnp.ndarray,
                         op: str, n_pad: int):
    """Flat-(E,) twin of ``sorted_segments``: sort by (worker, target),
    segmented reduce.  Returns ``(real, seg_t, seg_val, seg_w, ident)``
    — one entry per distinct live (source worker, target) pair.  Shared
    by the single-device path below and the sharded executor."""
    ident = identity_of(op, values.dtype)
    E = targets.shape[0]
    t = jnp.where(mask, targets, n_pad)          # sentinel sorts last
    order, ws, ts, first = sort_by_worker_target(src_worker, t)
    vs = jnp.where(feat_mask(mask, values, 1), values, ident)[order]

    seg_id = (jnp.cumsum(first) - 1).astype(jnp.int32)
    seg_fn = {"min": jax.ops.segment_min, "max": jax.ops.segment_max,
              "sum": jax.ops.segment_sum}[op]
    seg_val = seg_fn(vs, seg_id, num_segments=E)
    seg_t = jax.ops.segment_min(ts, seg_id, num_segments=E)
    seg_w = jax.ops.segment_min(ws, seg_id, num_segments=E)
    live = jnp.zeros((E,), bool).at[seg_id].set(True)
    real = live & (seg_t < n_pad)
    return real, seg_t, seg_val, seg_w, ident


def combine_sorted_flat(targets: jnp.ndarray, values: jnp.ndarray,
                        mask: jnp.ndarray, src_worker: jnp.ndarray,
                        op: str, M: int, n_loc: int,
                        log_of: Optional[np.ndarray] = None
                        ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray,
                                                      jnp.ndarray]]:
    """CSR twin of ``combine_sorted``: flat (E,) targets/values/mask with
    explicit per-edge source workers.  Sort by (worker, target), then a
    segmented reduce and one flat (n_pad,) scatter.  Combined counts are
    identical to the dense path (distinct non-identity (source worker,
    destination vertex) pairs, destination remote).

    With a split partition ``src_worker`` holds physical shard ids (the
    combining granularity) and ``log_of`` maps them to the (M,) logical
    workers for crossness and the per-worker report."""
    ident = identity_of(op, values.dtype)
    n_pad = M * n_loc
    feat = feat_shape(values, 1)
    if targets.shape[0] == 0:
        return (jnp.full((M, n_loc) + feat, ident, values.dtype),
                (jnp.zeros((), jnp.int32), jnp.zeros((M,), jnp.int32)))
    real, seg_t, seg_val, seg_w, ident = sorted_segments_flat(
        targets, values, mask, src_worker, op, n_pad)

    buf = jnp.full((n_pad,) + feat, ident, values.dtype)
    buf = scatter_op(op, buf, jnp.where(real, seg_t, 0),
                     jnp.where(feat_mask(real, seg_val, 1), seg_val, ident))
    inbox = buf.reshape((M, n_loc) + feat)

    seg_log = seg_w if log_of is None else jnp.asarray(log_of)[seg_w]
    # mask-driven crossness (see combine_sorted): live segment == real send
    cross = real & (seg_t // n_loc != seg_log)
    msgs = cross.sum().astype(jnp.int32)
    per_worker = jnp.zeros((M,), jnp.int32).at[
        jnp.where(cross, seg_log, 0)].add(cross.astype(jnp.int32))
    return inbox, (msgs, per_worker)


# ---------------------------------------------------------------------------
# plan cache keyed on the partitioned graph
# ---------------------------------------------------------------------------

def get_plan(pg, kind: str, nb: Optional[int] = None,
             eb: Optional[int] = None) -> EdgePlan:
    """Lazily build (and memoize on ``pg``) the plan for one edge set:
    ``eg`` (Ch_msg, non-mirrored sources), ``all`` (full adjacency), or
    ``mir`` (mirror fan-out, destinations local to the hosting worker)."""
    cache: Dict = pg.plan_cache
    nb = nb or default_nb()
    key = (kind, nb, eb)
    if key in cache:
        return cache[key]
    if kind not in ("eg", "all", "mir"):
        raise ValueError(f"unknown plan kind: {kind!r}")
    if getattr(pg, "layout", "padded") == "csr":
        # flat edges feed the packer directly: no padded unpack, no mask.
        # A split partition combines per *physical shard*: the plan's
        # source-worker axis becomes the shard id (callers fold stats back
        # to logical workers through pg.phys_log).
        split = getattr(pg, "phys_log", None) is not None
        M_src = pg.M_phys if split else pg.M
        if kind in ("eg", "all"):
            src = np.asarray(pg.eg_src if kind == "eg" else pg.all_src)
            dst = np.asarray(pg.eg_dst if kind == "eg" else pg.all_dst)
            sw = (np.asarray(pg.eg_pw if kind == "eg" else pg.all_pw)
                  if split else src // pg.n_loc)
            plan = build_edge_plan_flat(sw, dst // pg.n_loc,
                                        dst % pg.n_loc, M_src, pg.M,
                                        pg.n_loc, nb, eb)
        else:
            # mirror fan-out is local: source worker == hosting worker
            edst = np.asarray(pg.mir_edst)
            sw = (np.asarray(pg.mir_pw) if split else edst // pg.n_loc)
            plan = build_edge_plan_flat(sw, edst // pg.n_loc,
                                        edst % pg.n_loc, M_src, pg.M,
                                        pg.n_loc, nb, eb)
    elif kind == "eg":
        dst = np.asarray(pg.eg_dst)
        plan = build_edge_plan(dst // pg.n_loc, dst % pg.n_loc,
                               np.asarray(pg.eg_mask), pg.M, pg.n_loc,
                               nb, eb)
    elif kind == "all":
        dst = np.asarray(pg.all_dst)
        plan = build_edge_plan(dst // pg.n_loc, dst % pg.n_loc,
                               np.asarray(pg.all_mask), pg.M, pg.n_loc,
                               nb, eb)
    else:
        edst = np.asarray(pg.mir_edst)
        own = np.broadcast_to(np.arange(pg.M)[:, None], edst.shape)
        plan = build_edge_plan(own, edst, np.asarray(pg.mir_emask),
                               pg.M, pg.n_loc, nb, eb)
    cache[key] = plan
    return plan

"""BSP superstep runtime: jit-compiled while-loop with halt voting,
aggregators, and per-superstep message accounting.

A *program* is a function ``step(state, superstep) -> (state, halted, stats)``
where ``state`` is any pytree of (M, ...) arrays, ``halted`` a scalar bool
(the paper's "all vertices voted to halt & no pending messages"), and
``stats`` a flat dict of scalars / (M,) arrays.  The runtime accumulates
stats totals and an optional per-superstep history, and supports
checkpoint/restore of the loop carry (fault tolerance: the whole BSP state
is a pytree).

``run`` also executes unchanged *inside* a ``shard_map`` region (the
sharded executor in ``core/exec.py``): the step then computes ``halted``
and the stats with cross-device collectives so the carried halt flag and
accumulated totals are replicated across the mesh.

Stats contract: every ``per_worker_*`` entry is an (M,) array over the
*logical* workers.  Split partitions (``balance="split"``) run their
channels per physical shard, but the channel layer folds shard counts back
through ``pg.phys_log`` before the stats reach this loop — accumulation
here never needs to know how many physical shards a worker was split into,
and histories/totals stay comparable across balance modes and device
counts.

Overflow contract: per-superstep counts are int32 (a single superstep of
even a billion-edge graph fits), but multi-superstep TOTALS of the
nightly-scale runs approach 2^31.  Totals are therefore carried as
(hi, lo) int32 limb pairs inside the jitted loop — ``lo`` wraps mod 2^32
with an unsigned-compare carry into ``hi`` — and folded into Python
ints / numpy int64 on the host once the loop finishes (``jax_enable_x64``
stays off).  Integer histories stay int32 per superstep.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SIGN = -2 ** 31  # int32 sign bit: xor flips signed compare into unsigned


def _is_int(leaf) -> bool:
    return jnp.issubdtype(leaf.dtype, jnp.integer)


def _ult(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise unsigned a < b on int32 (two's-complement trick)."""
    s = jnp.int32(_SIGN)
    return jnp.bitwise_xor(a, s) < jnp.bitwise_xor(b, s)


def acc_init(stats_leaves):
    """Zero accumulator: (hi, lo) int32 pairs for integer leaves, the
    leaf's own dtype for floats."""
    return [
        (jnp.zeros(s.shape, jnp.int32), jnp.zeros(s.shape, jnp.int32))
        if _is_int(s) else jnp.zeros(s.shape, s.dtype)
        for s in stats_leaves
    ]


def acc_add(acc, stats_leaves):
    """Add one superstep's (non-negative int32) counts into the limbs."""
    out = []
    for a, s in zip(acc, stats_leaves):
        if isinstance(a, tuple):
            hi, lo = a
            new = lo + s.astype(jnp.int32)          # wraps mod 2^32
            carry = _ult(new, lo).astype(jnp.int32)  # s >= 0: wrap <=> ult
            out.append((hi + carry, new))
        else:
            out.append(a + s)
    return out


def finalize_totals(acc, treedef):
    """HOST-side fold of the limb pairs into exact numpy int64 (scalars
    become Python ints) — never call on tracers."""
    out = []
    for a in acc:
        if isinstance(a, tuple):
            hi = np.asarray(a[0]).astype(np.int64)
            lo = np.asarray(a[1]).astype(np.int64) & 0xFFFFFFFF
            tot = (hi << 32) + lo
            out.append(int(tot) if tot.ndim == 0 else tot)
        else:
            out.append(np.asarray(a))
    return jax.tree.unflatten(treedef, out)


def run(step: Callable, state, max_supersteps: int,
        record_history: bool = False, raw_totals: bool = False,
        pipeline: bool = False
        ) -> Tuple[object, Dict, jnp.ndarray, Optional[Dict]]:
    """Run ``step`` until halt or max_supersteps.

    Always returns the 4-tuple ``(final_state, stats_totals, n_supersteps,
    history)`` — ``history`` is the per-superstep stats pytree (leading
    ``max_supersteps`` axis) when ``record_history=True`` and ``None``
    otherwise, so callers never have to special-case the arity.

    ``raw_totals=False`` (the default) folds the carried (hi, lo) limb
    pairs into exact host-side Python ints / numpy int64.  The sharded
    executor runs this loop *inside* ``shard_map`` where no host exists;
    it passes ``raw_totals=True`` to get the raw limb list back (fold it
    with ``finalize_totals`` + the treedef of the per-superstep stats
    once outside the jit boundary).

    ``pipeline=True`` double-buffers the (hi, lo) limb fold: superstep
    ``i``'s counts are carried one iteration and folded while superstep
    ``i+1``'s exchange is in flight (the last pending superstep folds in
    an epilogue after the loop).  Limb addition is associative and the
    initial pending slot is all-zero, so totals are bit-identical to the
    unpipelined fold — the flag only moves the add off the superstep's
    critical path.
    """
    _, _, stats0 = jax.eval_shape(step, state, jnp.zeros((), jnp.int32))
    leaves0, treedef = jax.tree.flatten(stats0)
    zero_acc = acc_init(leaves0)
    zero_pending = [jnp.zeros(s.shape, s.dtype) for s in leaves0]
    history0 = None
    if record_history:
        history0 = jax.tree.map(
            lambda s: jnp.zeros((max_supersteps,) + s.shape, s.dtype), stats0)

    def cond(carry):
        _, halted, i, _, _, _ = carry
        return (~halted) & (i < max_supersteps)

    def body(carry):
        st, _, i, acc, hist, pending = carry
        st, halted, stats = step(st, i)
        leaves = jax.tree.leaves(stats)
        if pipeline:
            # fold the PREVIOUS superstep's counts while this superstep's
            # exchange is still in flight; stash this one for the next
            # iteration (or the epilogue)
            acc = acc_add(acc, pending)
            pending = leaves
        else:
            acc = acc_add(acc, leaves)
        if record_history:
            hist = jax.tree.map(lambda h, s: h.at[i].set(s), hist, stats)
        return st, halted, i + 1, acc, hist, pending

    carry = (state, jnp.zeros((), bool), jnp.zeros((), jnp.int32),
             zero_acc, history0, zero_pending)
    st, _, n, acc, hist, pending = jax.lax.while_loop(cond, body, carry)
    if pipeline:
        acc = acc_add(acc, pending)          # the last deferred superstep
    if raw_totals:
        return st, acc, n, hist
    return st, finalize_totals(acc, treedef), n, hist


def aggregate_or(x: jnp.ndarray) -> jnp.ndarray:
    """Aggregator: global OR (e.g. 'did any vertex update?')."""
    return jnp.any(x)


def aggregate_sum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x)

"""BSP superstep runtime: jit-compiled while-loop with halt voting,
aggregators, and per-superstep message accounting.

A *program* is a function ``step(state, superstep) -> (state, halted, stats)``
where ``state`` is any pytree of (M, ...) arrays, ``halted`` a scalar bool
(the paper's "all vertices voted to halt & no pending messages"), and
``stats`` a flat dict of scalars / (M,) arrays.  The runtime accumulates
stats totals and an optional per-superstep history, and supports
checkpoint/restore of the loop carry (fault tolerance: the whole BSP state
is a pytree).

``run`` also executes unchanged *inside* a ``shard_map`` region (the
sharded executor in ``core/exec.py``): the step then computes ``halted``
and the stats with cross-device collectives so the carried halt flag and
accumulated totals are replicated across the mesh.

Stats contract: every ``per_worker_*`` entry is an (M,) array over the
*logical* workers.  Split partitions (``balance="split"``) run their
channels per physical shard, but the channel layer folds shard counts back
through ``pg.phys_log`` before the stats reach this loop — accumulation
here never needs to know how many physical shards a worker was split into,
and histories/totals stay comparable across balance modes and device
counts.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def run(step: Callable, state, max_supersteps: int,
        record_history: bool = False
        ) -> Tuple[object, Dict, jnp.ndarray, Optional[Dict]]:
    """Run ``step`` until halt or max_supersteps.

    Always returns the 4-tuple ``(final_state, stats_totals, n_supersteps,
    history)`` — ``history`` is the per-superstep stats pytree (leading
    ``max_supersteps`` axis) when ``record_history=True`` and ``None``
    otherwise, so callers never have to special-case the arity.
    """
    _, _, stats0 = jax.eval_shape(step, state, jnp.zeros((), jnp.int32))
    zero_stats = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), stats0)
    history0 = None
    if record_history:
        history0 = jax.tree.map(
            lambda s: jnp.zeros((max_supersteps,) + s.shape, s.dtype), stats0)

    def cond(carry):
        _, halted, i, _, _ = carry
        return (~halted) & (i < max_supersteps)

    def body(carry):
        st, _, i, acc, hist = carry
        st, halted, stats = step(st, i)
        acc = jax.tree.map(jnp.add, acc, stats)
        if record_history:
            hist = jax.tree.map(lambda h, s: h.at[i].set(s), hist, stats)
        return st, halted, i + 1, acc, hist

    carry = (state, jnp.zeros((), bool), jnp.zeros((), jnp.int32),
             zero_stats, history0)
    st, _, n, acc, hist = jax.lax.while_loop(cond, body, carry)
    return st, acc, n, hist


def aggregate_or(x: jnp.ndarray) -> jnp.ndarray:
    """Aggregator: global OR (e.g. 'did any vertex update?')."""
    return jnp.any(x)


def aggregate_sum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x)

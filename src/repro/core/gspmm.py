"""gSpMM as a channel join: generalized sparse-dense aggregation on the
BSP engine's message channels, with feature-blocked (lanes, F) payloads.

The three DGL-style generalized SpMM primitives are expressed as ONE
``channels.broadcast`` join each — the same Ch_msg (sender-side combined)
+ Ch_mir (mirror fan-out) pipeline every algorithm rides, so the paper's
message-reduction guarantees (Theorem 1 combining, mirror broadcast for
high-degree vertices) apply to GNN aggregation unchanged:

    copy_u_sum :  out[v] = sum_{(u,v) in E}  x[u]
    u_mul_e_sum:  out[v] = sum_{(u,v) in E}  x[u] * w(u,v)
    u_mul_e_max:  out[v] = max_{(u,v) in E}  x[u] * w(u,v)

``x`` is the (M, n_loc, F) vertex-feature state (device-local
(m_loc, n_loc, F) inside the sharded executor); the edge weight
broadcasts over the feature axis (``relay="mul_w"``).

Differentiation: the sum joins carry a ``jax.custom_vjp``.  On the
symmetrized graphs the engine operates on (every edge stored in both
directions, w(u,v) = w(v,u)), the adjoint of the weighted segment-sum is
the SAME weighted broadcast applied to the cotangent:

    d/dx [ sum_v <g[v], out[v]> ]  =  A^T (W * g)  =  A (W * g)

so the backward pass is one more channel join — mirror broadcast,
destination-routed exchange and all — instead of XLA differentiating
through the sort/scatter internals.  Inside ``shard_map`` the backward
join issues the same collectives as the forward, which keeps the
gradient of each device's feature rows complete without any replicated
O(n) buffer.  ``u_mul_e_max`` is forward-only (aggregation for
inference-style pooling; no VJP is defined).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import channels

GSPMM_KINDS = ("copy_u_sum", "u_mul_e_sum", "u_mul_e_max")

_KIND = {
    "copy_u_sum": ("sum", "none"),
    "u_mul_e_sum": ("sum", "mul_w"),
    "u_mul_e_max": ("max", "mul_w"),
}


def _join(g, op: str, relay: str, backend: str, use_mirroring: bool):
    """The raw (non-differentiable) channel join: feats -> (out, stats)."""
    def apply(feats):
        active = jnp.ones(feats.shape[:2], bool)
        return channels.broadcast(g, feats, active, op, relay=relay,
                                  use_mirroring=use_mirroring,
                                  backend=backend)
    return apply


def gspmm_join(g, kind: str, backend: str = "dense",
               use_mirroring: bool = True):
    """Build the differentiable gSpMM aggregation for graph context ``g``
    (a PartitionedGraph, or the device-local ShardedGraph inside a
    ``shard_map`` body — the join then lowers to real collectives).

    Returns ``fn(feats) -> out`` with feats/out (rows, n_loc, F).
    Message stats are computed in the forward join and dropped — call
    :func:`gspmm_stats` for the accounting.  The sum kinds define a
    custom VJP (one mirror-broadcast join of the cotangent; requires the
    symmetrized edge set the engine stores); ``u_mul_e_max`` is
    forward-only."""
    if kind not in GSPMM_KINDS:
        raise ValueError(f"unknown gSpMM kind {kind!r}; "
                         f"use one of {GSPMM_KINDS}")
    op, relay = _KIND[kind]
    apply = _join(g, op, relay, backend, use_mirroring)

    if op != "sum":
        def fwd_only(feats):
            out, _ = apply(feats)
            # empty inboxes hold the max identity (-inf); zero-fill like
            # the dense segment-max convention so downstream dense math
            # (activations, matmuls) never sees non-finite values
            return jnp.where(jnp.isinf(out), jnp.zeros((), out.dtype), out)
        return fwd_only

    @jax.custom_vjp
    def f(feats):
        return apply(feats)[0]

    def f_fwd(feats):
        return apply(feats)[0], None

    def f_bwd(_, gout):
        # self-adjoint on the symmetrized edge set: A == A^T, w symmetric
        return (apply(gout)[0],)

    f.defvjp(f_fwd, f_bwd)
    return f


def gspmm_stats(g, kind: str, feats, backend: str = "dense",
                use_mirroring: bool = True) -> Tuple[jnp.ndarray, dict]:
    """Run the join once returning ``(out, stats)`` — the message
    accounting (msgs_combined / msgs_mirror / per-worker loads) for the
    aggregation, identical to any other channel join's stats."""
    op, relay = _KIND[kind]
    return _join(g, op, relay, backend, use_mirroring)(feats)


def copy_u_sum(g, feats, backend: str = "dense"):
    """out[v] = sum of neighbour features (differentiable)."""
    return gspmm_join(g, "copy_u_sum", backend)(feats)


def u_mul_e_sum(g, feats, backend: str = "dense"):
    """out[v] = weighted sum of neighbour features (differentiable)."""
    return gspmm_join(g, "u_mul_e_sum", backend)(feats)


def u_mul_e_max(g, feats, backend: str = "dense"):
    """out[v] = weighted max over neighbour features (forward-only;
    empty inboxes are zero-filled)."""
    return gspmm_join(g, "u_mul_e_max", backend)(feats)


def gspmm_sharded(pg, kind: str, feats, devices=1, backend: str = "dense",
                  pipeline: bool = False, use_mirroring: bool = True):
    """One-shot sharded gSpMM: runs the join over the device mesh
    (``devices`` an int or ``(hosts, per_host)``) and returns
    ``(out, stats)`` with ``out`` (M, n_loc, F) gathered back.  Parity
    contract follows the executor: max bitwise, sum within exchange
    round-off, stats integer-exact."""
    from repro.core import exec as exec_mod

    def mk(g):
        def fn(x):
            return gspmm_stats(g, kind, x, backend=backend,
                               use_mirroring=use_mirroring)
        return fn

    kinds = (exec_mod.broadcast_plan_kinds(backend, use_mirroring)
             if backend == "pallas" else ())
    return exec_mod.apply_sharded(pg, mk, (feats,), devices=devices,
                                  plan_kinds=kinds, pipeline=pipeline)

"""Sharded superstep executor: the worker axis as a real device mesh.

On one device the engine simulates the paper's M workers as a batch axis;
this module makes the simulation *distributed*: ``jax.jit`` + ``shard_map``
over a 1-D device mesh (axis ``"w"``, built via ``launch/mesh.make_mesh``)
shards the worker axis across D devices (M % D == 0, m = M/D workers per
device), and the channel joins lower to real collectives:

* Ch_msg, dense backend — each device builds only its m source workers'
  partial buffers (m, M, n_loc); the worker-axis transpose that the
  single-device path writes as ``swapaxes(partial3, 0, 1)`` becomes a real
  ``jax.lax.all_to_all`` over the mesh axis, after which every device
  reduces the full source axis for its local destinations in the same
  order as the reference path.
* Ch_msg, pallas/plan backend — the destination-blocked rows are packed
  *per device* at plan-build time (each device's plan covers its own
  workers' outgoing edges, row/segment counts padded to the device
  maximum); each device runs ``segment_combine_blocks`` on its rows and
  the per-device (n_blocks, nb) partials meet in a psum-style exchange
  (``pmin``/``pmax``/``psum`` matching the combine op) before each device
  slices out its destination blocks.
* Ch_mir — the mirror values are assembled with the same op-matched
  all-reduce (each device contributes the mirrored vertices it owns, the
  identity elsewhere: the all-gather payload of the paper), and the
  fan-out runs on destination-sharded mirror edges.
* Ch_req — the gather transports values with an ``all_gather`` of the
  (m, n_loc) value shards; the request/response *accounting* (Theorem 3
  dedup, per-worker charges on both requester and owner) is computed
  per device and psum-merged, identical to the reference counts.
* runtime-target scatters (S-V/MSF hooking) — per-device sorted segmented
  combine into a global (n_pad,) buffer, op-matched all-reduce, local
  slice.

Parity contract (pinned by tests/test_conformance.py's sharded axis and
``launch/shard_check.py``): for every algorithm x backend x layout,
``devices=D`` produces final state bitwise identical to the single-device
path for integer / min / max combines (sum combines like PageRank agree to
float round-off of the exchange reduction) and *every* ``msgs_*`` /
``per_worker_*`` statistic is integer-exact.

The flat CSR edge arrays are consumed per shard: each device receives the
contiguous slice of edges owned by its workers (edges are stored sorted by
owner), padded to the per-device maximum — O(E/D + M + n/D) per device,
never the padded (M, E_hot) wall.

Load balancing (``partition(..., balance="split")``): the partition's
*physical shards* (hot workers split by csr row-offset boundaries) become
the unit of device placement — ``device_edge_bounds`` packs contiguous
shard runs onto devices minimizing the bottleneck edge load, so device
boundaries are edge-balanced instead of worker-aligned.  A logical
worker's shards may then land on different devices while its vertex state
stays block-sharded, so the split executor (a) reads source values through
an ``all_gather`` of the state shards, (b) keys sender-side combining and
request dedup by physical shard (a shard never straddles devices, so
per-device accounting composes exactly), and (c) joins inboxes through the
op-matched global-buffer all-reduce — min/max results stay bitwise
identical to the single-device split simulation and every stat
integer-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import bsp
from repro.core import cost_model
from repro.core import plan as planlib
from repro.core.channels import _dedup_row, _reduce_op
from repro.core.plan import identity_of, scatter_op
from repro.launch import mesh as meshlib

AXIS = "w"

_MERGE = {"min": jnp.minimum, "max": jnp.maximum, "sum": jnp.add}


def _preduce(op: str, x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Cross-device all-reduce matching the combine op."""
    return {"min": jax.lax.pmin, "max": jax.lax.pmax,
            "sum": jax.lax.psum}[op](x, axis)


def broadcast_plan_kinds(backend: str, use_mirroring: bool = True) -> tuple:
    """The message plans the executor must pre-build (per device) for one
    ``channels.broadcast`` configuration — channel-layer knowledge kept in
    one place so the algorithms can't drift."""
    if backend != "pallas":
        return ()
    return ("eg", "mir") if use_mirroring else ("all",)


def graph_mesh(devices: int):
    """1-D worker mesh over the first ``devices`` devices."""
    if devices > len(jax.devices()):
        raise RuntimeError(
            f"requested {devices} devices but only {len(jax.devices())} "
            f"are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices} before "
            f"importing jax (graph_run --devices does this for you)")
    return meshlib.make_mesh((devices,), (AXIS,))


# ---------------------------------------------------------------------------
# per-device plan stacking (pallas backend)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TracedPlan:
    """Device-local view of one per-device edge plan inside ``shard_map``.

    Row/segment counts are padded to the maximum across devices; dummy rows
    have ``row_valid`` all-False (they combine to identity and scatter into
    segment 0 harmlessly) and dummy segments stay at the identity, so they
    never contribute to inboxes or message counts."""
    nb: int
    eb: int
    B_per_w: int
    n_blocks: int
    n_rows: int                # padded maximum
    n_segs: int                # padded maximum
    row_gather: jnp.ndarray    # (n_rows, eb) -> local flat edge index
    row_valid: jnp.ndarray     # (n_rows, eb)
    row_local: jnp.ndarray     # (n_rows, eb)
    row_seg: jnp.ndarray       # (n_rows,)
    seg_blk: jnp.ndarray       # (n_segs,) global block id
    seg_worker: jnp.ndarray    # (n_segs,) global source worker


def _device_plans(pg, D: int, kind: str, nb: int):
    """One EdgePlan per device covering that device's workers' edges, with
    *global* source-worker ids in ``seg_worker`` (message accounting) and
    *global* destination blocks (the exchange address space).  For a split
    partition the device slices follow the physical-shard bounds and
    ``seg_worker`` holds shard ids (combining granularity)."""
    M, n_loc = pg.M, pg.n_loc
    m = M // D
    split = _is_split(pg)
    dbounds = device_edge_bounds(pg, D) if split else None

    def build(d, eb):
        if pg.layout == "csr":
            M_src = pg.M_phys if split else M
            if kind in ("eg", "all"):
                src = np.asarray(pg.eg_src if kind == "eg" else pg.all_src)
                dst = np.asarray(pg.eg_dst if kind == "eg" else pg.all_dst)
                if split:
                    s, e = int(dbounds[kind][d]), int(dbounds[kind][d + 1])
                    pw = np.asarray(pg.eg_pw if kind == "eg"
                                    else pg.all_pw)
                    sw = pw[s:e]
                else:
                    off = pg.eg_off if kind == "eg" else pg.all_off
                    s, e = int(off[d * m]), int(off[(d + 1) * m])
                    sw = src[s:e] // n_loc
                return planlib.build_edge_plan_flat(
                    sw, dst[s:e] // n_loc, dst[s:e] % n_loc,
                    M_src, M, n_loc, nb, eb)
            edst = np.asarray(pg.mir_edst)
            if split:
                s, e = int(dbounds["mir"][d]), int(dbounds["mir"][d + 1])
                sw = np.asarray(pg.mir_pw)[s:e]
            else:
                s, e = int(pg.mir_eoff[d * m]), int(pg.mir_eoff[(d + 1) * m])
                sw = edst[s:e] // n_loc
            return planlib.build_edge_plan_flat(
                sw, edst[s:e] // n_loc, edst[s:e] % n_loc,
                M_src, M, n_loc, nb, eb)
        sl = slice(d * m, (d + 1) * m)
        if kind in ("eg", "all"):
            dst = np.asarray(pg.eg_dst if kind == "eg" else pg.all_dst)[sl]
            mask = np.asarray(pg.eg_mask if kind == "eg"
                              else pg.all_mask)[sl]
            p = planlib.build_edge_plan(dst // n_loc, dst % n_loc, mask,
                                        M, n_loc, nb, eb)
        else:
            edst = np.asarray(pg.mir_edst)[sl]
            own = np.broadcast_to(np.arange(d * m, (d + 1) * m)[:, None],
                                  edst.shape)
            p = planlib.build_edge_plan(own, edst,
                                        np.asarray(pg.mir_emask)[sl],
                                        M, n_loc, nb, eb)
        # build_edge_plan derives source workers from the (local) row index
        p.seg_worker = (p.seg_worker + d * m).astype(np.int32)
        return p

    plans = [build(d, None) for d in range(D)]
    eb = max(p.eb for p in plans)
    plans = [p if p.eb == eb else build(d, eb)
             for d, p in enumerate(plans)]
    return plans


def _stack_plans(plans):
    """Pad per-device plans to common row/segment counts and stack with a
    leading device axis.  Returns (static_meta, arrays_dict)."""
    D = len(plans)
    nb, eb = plans[0].nb, plans[0].eb
    R = max(1, max(p.n_rows for p in plans))
    S = max(1, max(p.n_segs for p in plans))
    a = {
        "row_gather": np.zeros((D, R, eb), np.int32),
        "row_valid": np.zeros((D, R, eb), bool),
        "row_local": np.full((D, R, eb), -1, np.int32),
        "row_seg": np.zeros((D, R), np.int32),
        "seg_blk": np.zeros((D, S), np.int32),
        "seg_worker": np.zeros((D, S), np.int32),
    }
    for d, p in enumerate(plans):
        a["row_gather"][d, :p.n_rows] = p.row_gather
        a["row_valid"][d, :p.n_rows] = p.row_valid
        a["row_local"][d, :p.n_rows] = p.row_local
        a["row_seg"][d, :p.n_rows] = p.row_seg
        a["seg_blk"][d, :p.n_segs] = p.seg_blk
        a["seg_worker"][d, :p.n_segs] = p.seg_worker
    meta = {"nb": nb, "eb": eb, "B_per_w": plans[0].B_per_w,
            "n_blocks": plans[0].n_blocks, "n_rows": R, "n_segs": S}
    return meta, a


# ---------------------------------------------------------------------------
# host-side graph sharding
# ---------------------------------------------------------------------------

def csr_device_bounds(off: np.ndarray, M: int, D: int) -> np.ndarray:
    """(D+1,) edge offsets at device boundaries of a (M+1,) worker csr."""
    m = M // D
    return np.asarray(off)[np.arange(0, M + 1, m)]


def _is_split(pg) -> bool:
    return getattr(pg, "phys_log", None) is not None


def device_edge_bounds(pg, D: int) -> Dict[str, np.ndarray]:
    """Per-device (D+1,) edge bounds for each csr edge set.

    Default partitions place boundaries at worker multiples (m = M/D
    workers per device).  Split partitions place them between *physical
    shards*, packed contiguously to minimize the bottleneck per-device
    eg+mir edge load (``"phys"`` holds the shard-index bounds)."""
    if _is_split(pg):
        loads = np.diff(pg.phys_eg_off) + np.diff(pg.phys_mir_off)
        pb = cost_model.contiguous_bounds(loads, D)
        return {"phys": pb,
                "eg": np.asarray(pg.phys_eg_off)[pb],
                "all": np.asarray(pg.phys_all_off)[pb],
                "mir": np.asarray(pg.phys_mir_off)[pb]}
    return {"phys": None,
            "eg": csr_device_bounds(pg.eg_off, pg.M, D),
            "all": csr_device_bounds(pg.all_off, pg.M, D),
            "mir": csr_device_bounds(pg.mir_eoff, pg.M, D)}


def device_edge_loads(pg, D: int) -> np.ndarray:
    """(D,) per-device superstep edge load (Ch_msg + mirror fan-out) the
    mesh placement yields — the number the bench-balance gate watches."""
    b = device_edge_bounds(pg, D)
    return np.diff(b["eg"]) + np.diff(b["mir"])


def _pad_device_slices(arr: np.ndarray, bounds: np.ndarray, pad_row):
    """Slice a flat (E,) array at ``bounds`` into (D, cap) with per-device
    padding values ``pad_row[d]``; also returns the validity mask."""
    D = len(bounds) - 1
    counts = np.diff(bounds)
    cap = max(1, int(counts.max()))
    out = np.empty((D, cap), arr.dtype)
    valid = np.zeros((D, cap), bool)
    for d in range(D):
        c = int(counts[d])
        out[d, :c] = arr[bounds[d]:bounds[d + 1]]
        out[d, c:] = pad_row[d]
        valid[d, :c] = True
    return out, valid


def _shard_graph(pg, D: int, plan_kinds: Sequence[str]):
    """Build the device-stacked array pytree + matching PartitionSpecs."""
    M, n_loc = pg.M, pg.n_loc
    m = M // D
    split = _is_split(pg)
    arrays: Dict = {"vmask": pg.vmask, "deg": pg.deg,
                    "mir_ids": pg.mir_ids, "mir_nworkers": pg.mir_nworkers}
    specs: Dict = {"vmask": P(AXIS), "deg": P(AXIS),
                   "mir_ids": P(), "mir_nworkers": P()}
    meta = {"M": M, "n_loc": n_loc, "D": D, "m_loc": m, "n": pg.n,
            "tau": pg.tau, "layout": pg.layout, "split": split,
            "plan_meta": {}}

    if pg.layout == "csr":
        dbounds = device_edge_bounds(pg, D) if split else None
        if split:
            pb = dbounds["phys"]
            meta["M_phys"] = pg.M_phys
            meta["p_bounds"] = pb
            meta["P_loc"] = int(np.diff(pb).max())
            meta["device_edge_load"] = device_edge_loads(pg, D)
            arrays["phys_log"] = jnp.asarray(pg.phys_log, jnp.int32)
            specs["phys_log"] = P()
        base = np.arange(D) * m * n_loc        # a safe in-range pad id
        for name, off_name in (("eg", "eg_off"), ("all", "all_off")):
            off = (dbounds[name] if split
                   else csr_device_bounds(getattr(pg, off_name), M, D))
            src, vs = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_src")), off, base)
            dst, _ = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_dst")), off, np.zeros(D))
            w, _ = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_w")), off, np.zeros(D))
            arrays[f"{name}_src"] = src
            arrays[f"{name}_dst"] = dst
            arrays[f"{name}_w"] = w
            arrays[f"{name}_mask"] = vs
            specs.update({f"{name}_src": P(AXIS), f"{name}_dst": P(AXIS),
                          f"{name}_w": P(AXIS), f"{name}_mask": P(AXIS)})
            if split:
                pw, _ = _pad_device_slices(
                    np.asarray(getattr(pg, f"{name}_pw")), off, pb[:-1])
                arrays[f"{name}_pw"] = pw
                specs[f"{name}_pw"] = P(AXIS)
        off = (dbounds["mir"] if split
               else csr_device_bounds(pg.mir_eoff, M, D))
        esrc, vs = _pad_device_slices(np.asarray(pg.mir_esrc), off,
                                      np.zeros(D))
        edst, _ = _pad_device_slices(np.asarray(pg.mir_edst), off, base)
        ew, _ = _pad_device_slices(np.asarray(pg.mir_ew), off, np.zeros(D))
        arrays.update(mir_esrc=esrc, mir_edst=edst, mir_ew=ew, mir_emask=vs)
        specs.update(mir_esrc=P(AXIS), mir_edst=P(AXIS), mir_ew=P(AXIS),
                     mir_emask=P(AXIS))
        if split:
            pw, _ = _pad_device_slices(np.asarray(pg.mir_pw), off, pb[:-1])
            arrays["mir_pw"] = pw
            specs["mir_pw"] = P(AXIS)
    else:
        for name in ("eg_src", "eg_dst", "eg_mask", "eg_w",
                     "all_src", "all_dst", "all_mask", "all_w",
                     "mir_esrc", "mir_edst", "mir_emask", "mir_ew"):
            arrays[name] = getattr(pg, name)
            specs[name] = P(AXIS)

    for kind in plan_kinds:
        pmeta, parrs = _stack_plans(_device_plans(pg, D, kind,
                                                  planlib.default_nb()))
        meta["plan_meta"][kind] = pmeta
        for k, v in parrs.items():
            arrays[f"plan_{kind}_{k}"] = v
            specs[f"plan_{kind}_{k}"] = P(AXIS)
    return meta, arrays, specs


# ---------------------------------------------------------------------------
# the inside-shard_map graph view
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedGraph:
    """Device-local twin of PartitionedGraph inside the ``shard_map`` body.

    Duck-types the fields algorithms and channels read — ``M``/``n_loc``
    stay *global* (owner arithmetic, per-worker stats), edge/vertex arrays
    are the local shard, and the ``g*`` reductions become collectives.
    ``channels.broadcast`` & friends detect the ``axis`` attribute and
    route to the sharded implementations below."""
    M: int
    n_loc: int
    m_loc: int
    D: int
    n: int
    tau: int
    layout: str
    axis: str
    w0: jnp.ndarray            # global index of this device's first worker
    vmask: jnp.ndarray
    deg: jnp.ndarray
    eg_src: jnp.ndarray
    eg_dst: jnp.ndarray
    eg_mask: jnp.ndarray
    eg_w: jnp.ndarray
    all_src: jnp.ndarray
    all_dst: jnp.ndarray
    all_mask: jnp.ndarray
    all_w: jnp.ndarray
    mir_ids: jnp.ndarray
    mir_nworkers: jnp.ndarray
    mir_esrc: jnp.ndarray
    mir_edst: jnp.ndarray
    mir_emask: jnp.ndarray
    mir_ew: jnp.ndarray
    plans: Dict[str, TracedPlan] = dataclasses.field(default_factory=dict)
    # split partitions (physical shards as the device placement unit):
    split: bool = False
    M_phys: int = 0
    P_loc: int = 0                      # max shards per device
    p0: Optional[jnp.ndarray] = None    # first shard id of this device
    phys_log: Optional[jnp.ndarray] = None   # replicated (M_phys,)
    eg_pw: Optional[jnp.ndarray] = None      # device-local per-edge shards
    all_pw: Optional[jnp.ndarray] = None
    mir_pw: Optional[jnp.ndarray] = None

    @property
    def n_pad(self) -> int:
        return self.M * self.n_loc

    def log_of(self, worker: jnp.ndarray) -> jnp.ndarray:
        """Physical shard ids -> logical worker ids (identity when the
        partition is not split)."""
        return self.phys_log[worker] if self.split else worker

    def gather_state(self, vals: jnp.ndarray) -> jnp.ndarray:
        """Replicate the (m_loc, n_loc) state shard to the full (M, n_loc)
        array — split partitions read source values globally because a
        device's edge slice can come from remote logical workers."""
        return jax.lax.all_gather(vals, self.axis, axis=0, tiled=True)

    def local_ids(self) -> jnp.ndarray:
        return ((self.w0 + jnp.arange(self.m_loc))[:, None] * self.n_loc
                + jnp.arange(self.n_loc)[None, :])

    def worker_ids(self) -> jnp.ndarray:
        """(m_loc,) global worker indices of the local rows."""
        return self.w0 + jnp.arange(self.m_loc)

    def gany(self, x):
        return jax.lax.psum(jnp.any(x).astype(jnp.int32), self.axis) > 0

    def gall(self, x):
        return jax.lax.psum((~jnp.all(x)).astype(jnp.int32), self.axis) == 0

    def gsum(self, x):
        return jax.lax.psum(jnp.sum(x), self.axis)

    def gmax(self, x):
        return jax.lax.pmax(jnp.max(x), self.axis)

    def edge_src_values(self, state, src):
        if self.layout == "csr":
            if self.split:
                return self.gather_state(state).reshape(-1)[src]
            return state.reshape(-1)[src - self.w0 * self.n_loc]
        return state[jnp.arange(self.m_loc)[:, None], src]


def _make_sg(meta, a) -> ShardedGraph:
    layout = meta["layout"]
    m = meta["m_loc"]
    d = jax.lax.axis_index(AXIS).astype(jnp.int32)
    w0 = d * m

    def loc(name):
        # csr edge leaves arrive as (1, cap) device rows; padded rows as
        # (m, ...) shards
        x = a[name]
        if layout == "csr" and name.split("_")[0] in ("eg", "all", "mir") \
                and name not in ("mir_ids", "mir_nworkers"):
            return x[0]
        return x

    plans = {}
    for kind, pm in meta["plan_meta"].items():
        plans[kind] = TracedPlan(
            nb=pm["nb"], eb=pm["eb"], B_per_w=pm["B_per_w"],
            n_blocks=pm["n_blocks"], n_rows=pm["n_rows"],
            n_segs=pm["n_segs"],
            row_gather=a[f"plan_{kind}_row_gather"][0],
            row_valid=a[f"plan_{kind}_row_valid"][0],
            row_local=a[f"plan_{kind}_row_local"][0],
            row_seg=a[f"plan_{kind}_row_seg"][0],
            seg_blk=a[f"plan_{kind}_seg_blk"][0],
            seg_worker=a[f"plan_{kind}_seg_worker"][0])
    split = meta.get("split", False)
    extra = {}
    if split:
        extra = dict(
            split=True, M_phys=meta["M_phys"], P_loc=meta["P_loc"],
            p0=jnp.asarray(meta["p_bounds"][:-1], jnp.int32)[d],
            phys_log=a["phys_log"], eg_pw=loc("eg_pw"),
            all_pw=loc("all_pw"), mir_pw=loc("mir_pw"))
    return ShardedGraph(
        M=meta["M"], n_loc=meta["n_loc"], m_loc=m, D=meta["D"],
        n=meta["n"], tau=meta["tau"], layout=layout, axis=AXIS, w0=w0,
        vmask=a["vmask"], deg=a["deg"],
        eg_src=loc("eg_src"), eg_dst=loc("eg_dst"),
        eg_mask=loc("eg_mask"), eg_w=loc("eg_w"),
        all_src=loc("all_src"), all_dst=loc("all_dst"),
        all_mask=loc("all_mask"), all_w=loc("all_w"),
        mir_ids=a["mir_ids"], mir_nworkers=a["mir_nworkers"],
        mir_esrc=loc("mir_esrc"), mir_edst=loc("mir_edst"),
        mir_emask=loc("mir_emask"), mir_ew=loc("mir_ew"),
        plans=plans, **extra)


# ---------------------------------------------------------------------------
# sharded channel implementations
# ---------------------------------------------------------------------------

def _place_rows(sg: ShardedGraph, local_counts: jnp.ndarray) -> jnp.ndarray:
    """(m_loc,) per-local-worker counts -> replicated (M,) via psum."""
    full = jnp.zeros((sg.M,), local_counts.dtype)
    full = jax.lax.dynamic_update_slice(full, local_counts, (sg.w0,))
    return jax.lax.psum(full, sg.axis)


def _scatter_workers(sg: ShardedGraph, workers, flags) -> jnp.ndarray:
    """Count ``flags`` at global ``workers`` -> replicated (M,)."""
    pw = jnp.zeros((sg.M,), jnp.int32).at[
        jnp.where(flags, workers, 0)].add(flags.astype(jnp.int32))
    return jax.lax.psum(pw, sg.axis)


def _local_slice(sg: ShardedGraph, buf: jnp.ndarray) -> jnp.ndarray:
    """(n_pad,) global buffer -> this device's (m_loc, n_loc) rows."""
    loc = jax.lax.dynamic_slice(buf, (sg.w0 * sg.n_loc,),
                                (sg.m_loc * sg.n_loc,))
    return loc.reshape(sg.m_loc, sg.n_loc)


def _exchange_dense(sg: ShardedGraph, partial3: jnp.ndarray, op: str
                    ) -> jnp.ndarray:
    """(m_src, M, n_loc) local partials -> (m_dst, n_loc) inbox.

    The worker-axis transpose of the single-device path IS the all_to_all:
    after the exchange each device holds (M_src, m_dst, n_loc) ordered by
    global source worker, and reduces the full source axis exactly like
    the reference ``swapaxes`` + reduce."""
    m, D = sg.m_loc, sg.D
    x = partial3.reshape(m, D, m, sg.n_loc)
    y = jax.lax.all_to_all(x, sg.axis, split_axis=1, concat_axis=1)
    recv = jnp.transpose(y, (1, 0, 2, 3)).reshape(D * m, m, sg.n_loc)
    return _reduce_op(op, recv, axis=0)


def _combine_with_plan_sharded(sg: ShardedGraph, plan: TracedPlan,
                               flat_vals: jnp.ndarray, op: str,
                               count_cross: bool = True,
                               exchange: bool = True):
    """Per-device destination-blocked combine + psum-style exchange."""
    ident = identity_of(op, flat_vals.dtype)
    packed = jnp.where(plan.row_valid, flat_vals[plan.row_gather], ident)
    row_out = planlib._combine_rows(packed, plan.row_local, op, plan.nb)
    seg_buf = jnp.full((plan.n_segs, plan.nb), ident, flat_vals.dtype)
    seg_out = scatter_op(op, seg_buf, plan.row_seg, row_out)
    glob = jnp.full((plan.n_blocks, plan.nb), ident, flat_vals.dtype)
    glob = scatter_op(op, glob, plan.seg_blk, seg_out)
    if exchange:
        glob = _preduce(op, glob, sg.axis)
    rows = jax.lax.dynamic_slice_in_dim(glob, sg.w0 * plan.B_per_w,
                                        sg.m_loc * plan.B_per_w, 0)
    inbox = rows.reshape(sg.m_loc, plan.B_per_w * plan.nb)[:, :sg.n_loc]

    stats = None
    if count_cross:
        seg_log = sg.log_of(plan.seg_worker)
        owner = plan.seg_blk // plan.B_per_w
        cross = (seg_out != ident) & (owner != seg_log)[:, None]
        msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
        per_worker = jnp.zeros((sg.M,), jnp.int32).at[seg_log].add(
            cross.sum(axis=1).astype(jnp.int32))
        stats = (msgs, jax.lax.psum(per_worker, sg.axis))
    return inbox, stats


def _combine_sorted_rows_sharded(sg: ShardedGraph, targets, values, mask,
                                 op: str):
    """Sharded twin of plan.combine_sorted: the shared segment core
    (``plan.sorted_segments``) runs on the local (m_loc, K) rows, then the
    global (n_pad,) buffer meets in an op-matched all-reduce and the local
    slice is taken; source rows are rebased by ``w0`` for the accounting."""
    n_pad = sg.n_pad
    real, seg_t, seg_val, seg_row, ident = planlib.sorted_segments(
        targets, values, mask, op, n_pad)

    buf = jnp.full((n_pad,), ident, values.dtype)
    buf = scatter_op(op, buf, jnp.where(real, seg_t, 0),
                     jnp.where(real, seg_val, ident))
    inbox = _local_slice(sg, _preduce(op, buf, sg.axis))

    cross = real & (seg_val != ident) & (seg_t // sg.n_loc
                                         != seg_row + sg.w0)
    msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
    per_worker = _scatter_workers(sg, seg_row + sg.w0, cross)
    return inbox, (msgs, per_worker)


def _combine_sorted_flat_sharded(sg: ShardedGraph, targets, values, mask,
                                 worker, op: str):
    """Flat-csr twin: ``plan.sorted_segments_flat`` on the local (E_dev,)
    edges (source workers already global — physical shard ids under a
    split partition), all-reduce exchange, local slice."""
    n_pad = sg.n_pad
    real, seg_t, seg_val, seg_w, ident = planlib.sorted_segments_flat(
        targets, values, mask, worker, op, n_pad)

    buf = jnp.full((n_pad,), ident, values.dtype)
    buf = scatter_op(op, buf, jnp.where(real, seg_t, 0),
                     jnp.where(real, seg_val, ident))
    inbox = _local_slice(sg, _preduce(op, buf, sg.axis))

    seg_log = sg.log_of(jnp.where(real, seg_w, 0))
    cross = real & (seg_val != ident) & (seg_t // sg.n_loc != seg_log)
    msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
    per_worker = _scatter_workers(sg, seg_log, cross)
    return inbox, (msgs, per_worker)


def push_combined_sharded(sg: ShardedGraph, targets, values, mask, op: str,
                          backend: str = "dense",
                          plan: Optional[TracedPlan] = None):
    """Sharded Ch_msg, padded rows: local (m_loc, K) edges."""
    ident = identity_of(op, values.dtype)
    gw = sg.worker_ids()[:, None]
    raw_cross = mask & ((targets // sg.n_loc) != gw)
    base = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
            "per_worker_basic": _place_rows(sg, raw_cross.sum(axis=1))}

    if backend == "pallas":
        if plan is not None:
            masked = jnp.where(mask, values, ident)
            inbox, (msgs, pw) = _combine_with_plan_sharded(
                sg, plan, masked.reshape(-1), op)
        else:
            inbox, (msgs, pw) = _combine_sorted_rows_sharded(
                sg, targets, values, mask, op)
        stats = {"msgs_combined": msgs, "per_worker_combined": pw}
        stats.update(base)
        return inbox, stats

    n_pad = sg.n_pad

    def one(tgt, val, msk):
        v = jnp.where(msk, val, ident)
        t = jnp.where(msk, tgt, 0)
        buf = jnp.full((n_pad,), ident, values.dtype)
        return scatter_op(op, buf, t, v)

    partial = jax.vmap(one)(targets, values, mask)      # (m_loc, n_pad)
    partial3 = partial.reshape(sg.m_loc, sg.M, sg.n_loc)
    sent = partial3 != ident
    cross = sent & (jnp.arange(sg.M)[None, :, None] != gw[:, :, None])
    stats = {
        "msgs_combined": jax.lax.psum(cross.sum(), sg.axis),
        "per_worker_combined": _place_rows(sg, cross.sum(axis=(1, 2))),
    }
    stats.update(base)
    return _exchange_dense(sg, partial3, op), stats


def push_combined_flat_sharded(sg: ShardedGraph, targets, values, mask,
                               worker, op: str, backend: str = "dense",
                               plan: Optional[TracedPlan] = None):
    """Sharded Ch_msg, csr layout: local flat (E_dev,) edges with global
    per-edge source workers (physical shard ids under a split partition —
    a shard never straddles devices, so the per-device distinct-pair
    accounting composes exactly across any device count)."""
    ident = identity_of(op, values.dtype)
    wlog = sg.log_of(worker)
    raw_cross = mask & ((targets // sg.n_loc) != wlog)
    base = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
            "per_worker_basic": _scatter_workers(sg, wlog, raw_cross)}

    if backend == "pallas":
        if plan is not None:
            masked = jnp.where(mask, values, ident)
            inbox, (msgs, pw) = _combine_with_plan_sharded(
                sg, plan, masked, op)
        else:
            inbox, (msgs, pw) = _combine_sorted_flat_sharded(
                sg, targets, values, mask, worker, op)
        stats = {"msgs_combined": msgs, "per_worker_combined": pw}
        stats.update(base)
        return inbox, stats

    n_pad = sg.n_pad
    if sg.split:
        # device boundaries sit between physical shards, not at worker
        # multiples: the per-source partial is keyed by local shard and
        # the join is the op-matched global-buffer all-reduce (the
        # all_to_all needs a uniform per-device source count).
        lp = jnp.clip(worker - sg.p0, 0, sg.P_loc - 1)
        idx = lp * n_pad + jnp.where(mask, targets, 0)
        v = jnp.where(mask, values, ident)
        partial = jnp.full((sg.P_loc * n_pad,), ident, values.dtype)
        partial3 = scatter_op(op, partial, idx, v).reshape(sg.P_loc, sg.M,
                                                           sg.n_loc)
        sent = partial3 != ident
        row_log = sg.phys_log[jnp.clip(sg.p0 + jnp.arange(sg.P_loc),
                                       0, sg.M_phys - 1)]
        cross3 = sent & (jnp.arange(sg.M)[None, :, None]
                         != row_log[:, None, None])
        per_worker = jnp.zeros((sg.M,), jnp.int32).at[row_log].add(
            cross3.sum(axis=(1, 2)).astype(jnp.int32))
        stats = {
            "msgs_combined": jax.lax.psum(cross3.sum(), sg.axis),
            "per_worker_combined": jax.lax.psum(per_worker, sg.axis),
        }
        stats.update(base)
        buf = _reduce_op(op, partial3, axis=0).reshape(-1)
        inbox = _local_slice(sg, _preduce(op, buf, sg.axis))
        return inbox, stats

    idx = (worker - sg.w0) * n_pad + jnp.where(mask, targets, 0)
    v = jnp.where(mask, values, ident)
    partial = jnp.full((sg.m_loc * n_pad,), ident, values.dtype)
    partial3 = scatter_op(op, partial, idx, v).reshape(sg.m_loc, sg.M,
                                                       sg.n_loc)
    sent = partial3 != ident
    gw = sg.worker_ids()[:, None]
    cross3 = sent & (jnp.arange(sg.M)[None, :, None] != gw[:, :, None])
    stats = {
        "msgs_combined": jax.lax.psum(cross3.sum(), sg.axis),
        "per_worker_combined": _place_rows(sg, cross3.sum(axis=(1, 2))),
    }
    stats.update(base)
    return _exchange_dense(sg, partial3, op), stats


def push_mirror_sharded(sg: ShardedGraph, vals, active, op: str,
                        relay: str = "none", backend: str = "dense"):
    """Sharded Ch_mir: op-matched all-reduce assembles the mirror values
    (each device contributes the mirrored vertices it owns), then the
    fan-out runs on the destination-sharded mirror edges."""
    ident = identity_of(op, vals.dtype)
    n_pad = sg.n_pad
    m_slots = sg.m_loc * sg.n_loc
    safe_g = jnp.clip(sg.mir_ids, 0, n_pad - 1)
    valid = sg.mir_ids < n_pad
    slot = safe_g - sg.w0 * sg.n_loc
    owned = (slot >= 0) & (slot < m_slots)
    sl = jnp.clip(slot, 0, m_slots - 1)
    flat_vals = vals.reshape(-1)
    flat_act = active.reshape(-1)
    contrib = jnp.where(valid & owned & flat_act[sl], flat_vals[sl], ident)
    mir_vals = _preduce(op, contrib, sg.axis)      # replicated (n_mir,)

    raw = mir_vals[sg.mir_esrc]
    ev = raw + sg.mir_ew if relay == "add_w" else raw
    ev = jnp.where(sg.mir_emask & (raw != ident), ev, ident)
    if backend == "pallas":
        # split partitions can hold mirror edges whose destination worker
        # lives on another device: exchange the destination blocks
        inbox, _ = _combine_with_plan_sharded(
            sg, sg.plans["mir"], ev.reshape(-1), op,
            count_cross=False, exchange=sg.split)
    elif sg.layout == "csr":
        if sg.split:
            buf = jnp.full((n_pad,), ident, vals.dtype)
            buf = scatter_op(op, buf, sg.mir_edst, ev)
            inbox = _local_slice(sg, _preduce(op, buf, sg.axis))
        else:
            buf = jnp.full((m_slots,), ident, vals.dtype)
            inbox = scatter_op(op, buf, sg.mir_edst - sg.w0 * sg.n_loc,
                               ev).reshape(sg.m_loc, sg.n_loc)
    else:
        def fan_out(edst, emask, ev_row):
            buf = jnp.full((sg.n_loc,), ident, vals.dtype)
            return scatter_op(op, buf, jnp.where(emask, edst, 0), ev_row)

        inbox = jax.vmap(fan_out)(sg.mir_edst, sg.mir_emask, ev)

    # stats are computed from the replicated mirror values: every device
    # derives the identical (M,) array — no psum (it would double-count)
    sent = jnp.where(mir_vals != ident, sg.mir_nworkers, 0)
    owner_w = jnp.clip(safe_g // sg.n_loc, 0, sg.M - 1)
    per_worker = jnp.zeros((sg.M,), sent.dtype).at[owner_w].add(
        jnp.where(valid, sent, 0))
    return inbox, {"msgs_mirror": sent.sum(), "per_worker_mirror": per_worker}


def broadcast_sharded(sg: ShardedGraph, vals, active, op: str,
                      relay: str = "none", use_mirroring: bool = True,
                      backend: str = "dense"):
    """Sharded twin of channels.broadcast (identical stats keys/values)."""
    esrc = sg.eg_src if use_mirroring else sg.all_src
    edst = sg.eg_dst if use_mirroring else sg.all_dst
    emask = sg.eg_mask if use_mirroring else sg.all_mask
    ew = sg.eg_w if use_mirroring else sg.all_w
    plan = (sg.plans.get("eg" if use_mirroring else "all")
            if backend == "pallas" else None)
    if sg.layout == "csr":
        if sg.split:
            # edge-balanced device bounds: sources can be remote workers
            allv = sg.gather_state(vals).reshape(-1)
            alla = sg.gather_state(active).reshape(-1)
            src_val, src_act = allv[esrc], alla[esrc]
            worker = sg.eg_pw if use_mirroring else sg.all_pw
        else:
            loc_src = esrc - sg.w0 * sg.n_loc
            src_val = vals.reshape(-1)[loc_src]
            src_act = active.reshape(-1)[loc_src]
            worker = esrc // sg.n_loc
        v = src_val + ew if relay == "add_w" else src_val
        inbox, stats = push_combined_flat_sharded(
            sg, edst, v, emask & src_act, worker, op,
            backend=backend, plan=plan)
    else:
        src_val = vals[jnp.arange(sg.m_loc)[:, None], esrc]
        src_act = active[jnp.arange(sg.m_loc)[:, None], esrc]
        v = src_val + ew if relay == "add_w" else src_val
        inbox, stats = push_combined_sharded(sg, edst, v, emask & src_act,
                                             op, backend=backend, plan=plan)
    if use_mirroring:
        inbox2, s2 = push_mirror_sharded(sg, vals, active, op, relay,
                                         backend=backend)
        inbox = _MERGE[op](inbox, inbox2)
        stats.update(s2)
    else:
        stats["msgs_mirror"] = jnp.zeros((), jnp.int32)
        stats["per_worker_mirror"] = jnp.zeros((sg.M,), jnp.int32)
    stats["msgs_total"] = stats["msgs_combined"] + stats["msgs_mirror"]
    stats["per_worker_total"] = (stats["per_worker_combined"]
                                 + stats["per_worker_mirror"])
    return inbox, stats


def gather_sharded(sg: ShardedGraph, vals, targets, tmask,
                   dedup: bool = True):
    """Sharded Ch_req for row-shaped targets (m_loc, R): the values travel
    in one all_gather of the (m, n_loc) shards; the request-respond
    *counts* (Theorem 3) are computed per device and psum-merged so they
    match the reference accounting exactly."""
    n_pad = sg.n_pad
    allv = jax.lax.all_gather(vals, sg.axis, axis=0, tiled=True)
    t = jnp.where(tmask, targets, n_pad)
    ok = tmask & (t < n_pad)
    out = jnp.where(ok, allv.reshape(-1)[jnp.clip(t, 0, n_pad - 1)],
                    jnp.zeros((), vals.dtype))

    if dedup:
        uniq, _ = jax.vmap(lambda r: _dedup_row(r, n_pad))(t)
    else:
        uniq = t
    owner = jnp.clip(uniq // sg.n_loc, 0, sg.M - 1)
    uvalid = uniq < n_pad
    self_w = sg.worker_ids()[:, None]
    remote_u = uvalid & (owner != self_w)
    raw_remote = tmask & ((targets // sg.n_loc) != self_w)
    raw_owner = jnp.clip(targets // sg.n_loc, 0, sg.M - 1)
    stats = {
        "msgs_rr": 2 * jax.lax.psum(remote_u.sum(), sg.axis),
        "msgs_basic": 2 * jax.lax.psum(raw_remote.sum(), sg.axis),
        "per_worker_rr": (_place_rows(sg, remote_u.sum(1))
                          + _scatter_workers(sg, owner, remote_u)),
        "per_worker_basic": (_place_rows(sg, raw_remote.sum(1))
                             + _scatter_workers(sg, raw_owner, raw_remote)),
    }
    return out, stats


def gather_edges_sharded(sg: ShardedGraph, vals, targets, tmask,
                         dedup: bool = True):
    """Sharded Ch_req for edge-shaped targets (layout-dispatching)."""
    if sg.layout != "csr":
        return gather_sharded(sg, vals, targets, tmask, dedup)
    n_pad = sg.n_pad
    worker = sg.all_pw if sg.split else sg.all_src // sg.n_loc
    wlog = sg.log_of(worker)
    allv = jax.lax.all_gather(vals, sg.axis, axis=0, tiled=True)
    t = jnp.where(tmask, targets, n_pad)
    ok = tmask & (t < n_pad)
    out = jnp.where(ok, allv.reshape(-1)[jnp.clip(t, 0, n_pad - 1)],
                    jnp.zeros((), vals.dtype))
    # (no E == 0 case: _pad_device_slices guarantees cap >= 1)
    owner = jnp.clip(targets // sg.n_loc, 0, sg.M - 1)
    raw_remote = tmask & ((targets // sg.n_loc) != wlog)
    if dedup:
        _, ws, ts, first = planlib.sort_by_worker_target(worker, t)
        ws_log = sg.log_of(ws)
        uniq = first & (ts < n_pad)
        remote_u = uniq & (ts // sg.n_loc != ws_log)
        u_w, u_owner = ws_log, jnp.clip(ts // sg.n_loc, 0, sg.M - 1)
    else:
        remote_u = raw_remote
        u_w, u_owner = wlog, owner
    stats = {
        "msgs_rr": 2 * jax.lax.psum(remote_u.sum(), sg.axis),
        "msgs_basic": 2 * jax.lax.psum(raw_remote.sum(), sg.axis),
        "per_worker_rr": (_scatter_workers(sg, u_w, remote_u)
                          + _scatter_workers(sg, u_owner, remote_u)),
        "per_worker_basic": (_scatter_workers(sg, wlog, raw_remote)
                             + _scatter_workers(sg, owner, raw_remote)),
    }
    return out, stats


def scatter_state_sharded(sg: ShardedGraph, base, targets, upd, mask,
                          op: str, backend: str = "dense"):
    """Sharded scatter-op for row-shaped runtime targets (S-V hooking).
    Runtime destinations admit no precomputed plan, so both backends share
    the sorted segmented combine + op-matched exchange (the reference
    paths' stats are identical by construction, and min/max values are
    order-exact)."""
    gw = sg.worker_ids()[:, None]
    raw_cross = mask & ((targets // sg.n_loc) != gw)
    bstats = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
              "per_worker_basic": _place_rows(sg, raw_cross.sum(axis=1))}
    inbox, (msgs, pw) = _combine_sorted_rows_sharded(sg, targets, upd,
                                                     mask, op)
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(bstats)
    return _MERGE[op](base, inbox), stats


def scatter_edges_sharded(sg: ShardedGraph, base, targets, upd, mask,
                          op: str, backend: str = "dense"):
    """Sharded scatter-op for edge-shaped runtime targets (MSF election)."""
    if sg.layout != "csr":
        return scatter_state_sharded(sg, base, targets, upd, mask, op,
                                     backend)
    worker = sg.all_pw if sg.split else sg.all_src // sg.n_loc
    wlog = sg.log_of(worker)
    raw_cross = mask & ((targets // sg.n_loc) != wlog)
    bstats = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
              "per_worker_basic": _scatter_workers(sg, wlog, raw_cross)}
    inbox, (msgs, pw) = _combine_sorted_flat_sharded(sg, targets, upd,
                                                     mask, worker, op)
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(bstats)
    return _MERGE[op](base, inbox), stats


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def _state_specs(tree, M: int):
    return jax.tree.map(
        lambda x: P(AXIS) if (getattr(x, "ndim", 0) >= 1
                              and x.shape[0] == M) else P(), tree)


def build_sharded(pg, make_step: Callable, state0, max_supersteps: int,
                  record_history: bool = False, devices: int = 1,
                  plan_kinds: Sequence[str] = ()):
    """Build the jitted sharded BSP program.  Returns (fn, args) with
    ``fn(*args) == (final_state, stats_totals, n_supersteps, history)`` —
    the same contract as ``bsp.run``.

    ``make_step(g)`` must build the superstep function against either a
    PartitionedGraph (used here only to trace the stats structure) or the
    device-local ShardedGraph."""
    if pg.M % devices:
        raise ValueError(f"M={pg.M} workers must divide over "
                         f"devices={devices}")
    mesh = graph_mesh(devices)
    meta, arrays, arr_specs = _shard_graph(pg, devices, plan_kinds)

    _, _, stats_shape = jax.eval_shape(make_step(pg), state0,
                                       jnp.zeros((), jnp.int32))
    st_specs = _state_specs(state0, pg.M)
    stats_specs = jax.tree.map(lambda _: P(), stats_shape)
    hist_specs = stats_specs if record_history else None

    def inner(arrs, st0):
        sg = _make_sg(meta, arrs)
        return bsp.run(make_step(sg), st0, max_supersteps, record_history)

    fn = shard_map(inner, mesh=mesh, in_specs=(arr_specs, st_specs),
                   out_specs=(st_specs, stats_specs, P(), hist_specs),
                   check_rep=False)
    return jax.jit(fn), (arrays, state0)


def run_sharded(pg, make_step: Callable, state0, max_supersteps: int,
                record_history: bool = False, devices: int = 1,
                plan_kinds: Sequence[str] = ()):
    """Run a BSP program sharded over ``devices`` devices; same return
    contract as ``bsp.run``."""
    fn, args = build_sharded(pg, make_step, state0, max_supersteps,
                             record_history, devices, plan_kinds)
    return fn(*args)


def apply_sharded(pg, make_fn: Callable, args: Tuple, devices: int = 1,
                  plan_kinds: Sequence[str] = ()):
    """One-shot sharded channel application (no BSP loop): ``make_fn(sg)``
    returns ``fn(*local_args) -> (out, stats)`` where every ``out`` leaf is
    worker/edge-sharded on its leading axis and ``stats`` is replicated.
    csr edge-shaped outputs come back device-concatenated with per-device
    padding — strip with ``csr_device_bounds``."""
    if pg.M % devices:
        raise ValueError(f"M={pg.M} workers must divide over "
                         f"devices={devices}")
    mesh = graph_mesh(devices)
    meta, arrays, arr_specs = _shard_graph(pg, devices, plan_kinds)
    in_specs = jax.tree.map(
        lambda x: P(AXIS) if (getattr(x, "ndim", 0) >= 1
                              and x.shape[0] == pg.M) else P(), args)
    out_shape, stats_shape = jax.eval_shape(make_fn(pg), *args)
    out_specs = (jax.tree.map(lambda _: P(AXIS), out_shape),
                 jax.tree.map(lambda _: P(), stats_shape))

    def inner(arrs, a):
        sg = _make_sg(meta, arrs)
        return make_fn(sg)(*a)

    fn = shard_map(inner, mesh=mesh, in_specs=(arr_specs, in_specs),
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)(arrays, args)

"""Sharded superstep executor: the worker axis as a real device mesh.

On one device the engine simulates the paper's M workers as a batch axis;
this module makes the simulation *distributed*: ``jax.jit`` + ``shard_map``
over a 1-D device mesh (axis ``"w"``, built via ``launch/mesh.make_mesh``)
shards the worker axis across D devices (M % D == 0, m = M/D workers per
device).

``devices=(hosts, per_host)`` instead builds the 2-D ``("h", "w")`` mesh
(``launch/mesh.graph_mesh``) and every routed join above becomes
*hierarchical*: lanes first route to the device of their destination
column WITHIN the sender's host (one intra-host ``all_to_all`` over
``"w"``), that device op-combines everything it received by destination
(requests: deduplicates — the paper's Theorem-1/Theorem-3 reductions
applied per routing level), and only the combined residue crosses the
host axis (a second ``all_to_all`` over ``"h"``).  Cross-host volume is
therefore bounded by the post-combine residue, never the raw fan-out —
the property ``exchange_volume_report`` measures and the bench gates
pin.  Each leg carries its own cap derived per level from
``pair_counts`` (``_cap_hints_2d``), and the double-buffered pipeline
overlaps the *inter-host* leg, where collective latency actually
hurts.  The flat device id d = h*T + t is the row-major mesh order, so
owner arithmetic, stats, and parity against the 1-D path are unchanged
(min/max/int bitwise, stats integer-exact).

Every channel join is **destination-routed**: messages (and requests)
travel straight to the device that owns their destination via
``jax.lax.all_to_all`` with fixed per-destination-device lane caps, and
each device only ever materializes O(n/D + E/D)-sized buffers.  No join
replicates global state — there is no ``all_gather`` of the value shards
and no op-matched all-reduce over a global (n_pad,) scatter buffer
anywhere in the superstep (the paper's Theorems 1/3 bound per-worker
*communication*; replicating O(n) state per device would void exactly
that bound, and makes multi-host meshes untenable).

* Ch_msg, pallas/plan backend — the destination-blocked rows are packed
  *per device* at plan-build time; each device runs
  ``segment_combine_blocks`` on its rows, then the per-(source, block)
  segment partials are exchanged with ONE ``all_to_all``: the plan is
  blocked per destination device at stack time (static exchange indices,
  exact caps — runtime never overflows), and each device scatters the
  received segments into its local (m·B_per_w, nb) block range only.
* Ch_msg, dense backend / runtime-target scatters (S-V/MSF hooking) —
  the shared sorted segmented combine (``plan.sorted_segments*``) reduces
  duplicate (source, target) pairs locally, then the surviving segments
  are bucketed by destination device (``target // (m·n_loc)``) and
  exchanged in cap-sized ``all_to_all`` rounds: a psum'd remaining-lanes
  count drives extra rounds when a hot destination overflows the cap, so
  skew costs extra rounds, never correctness (and never a recompile).
  Receivers combine into a local (m·n_loc,) buffer.
* Ch_mir — mirror values are routed from the owner device to exactly the
  devices hosting fan-out edges for them, through a static fetch plan
  (per-device needed-value lists computed at graph-shard time; one
  ``all_to_all``).  The fan-out then runs on the local mirror edges.
* Ch_req — a real two-round trip: deduplicated requests route to the
  owner devices (cap-sized ``all_to_all`` rounds), owners answer from
  their local (m, n_loc) shard, responses route back.  The Theorem-3
  accounting (dedup, per-worker charges on requester and owner) is
  computed per device and psum-merged, identical to the reference counts.

Parity contract (pinned by tests/test_conformance.py's sharded axis and
``launch/shard_check.py``): for every algorithm x backend x layout,
``devices=D`` produces final state bitwise identical to the single-device
path for integer / min / max combines (sum combines like PageRank agree to
float round-off of the exchange reduction) and *every* ``msgs_*`` /
``per_worker_*`` statistic is integer-exact.

The flat CSR edge arrays are consumed per shard: each device receives the
contiguous slice of edges owned by its workers (edges are stored sorted by
owner), padded to the per-device maximum — O(E/D + M + n/D) per device,
never the padded (M, E_hot) wall.

Load balancing (``partition(..., balance="split")``): the partition's
*physical shards* (hot workers split by csr row-offset boundaries) become
the unit of device placement — ``device_edge_bounds`` packs contiguous
shard runs onto devices minimizing the bottleneck edge load, so device
boundaries are edge-balanced instead of worker-aligned.  A logical
worker's shards may then land on different devices while its vertex state
stays block-sharded, so the split executor (a) reads source values through
a static fetch plan (each device's needed source slots are known at
graph-shard time — never an all_gather of the state), (b) keys sender-side
combining and request dedup by physical shard (a shard never straddles
devices, so per-device accounting composes exactly), and (c) joins inboxes
through the routed exchange — min/max results stay bitwise identical to
the single-device split simulation and every stat integer-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import bsp
from repro.core import cost_model
from repro.core import plan as planlib
from repro.core.channels import _dedup_row, relay_values
from repro.core.plan import identity_of, scatter_op
from repro.launch import mesh as meshlib

AXIS = "w"
HAXIS = "h"

_MERGE = {"min": jnp.minimum, "max": jnp.maximum, "sum": jnp.add}

# Exchange chunks per superstep join when the double-buffered pipeline is
# on: each routed exchange is split into ~this many cap-sized chunks so
# chunk k's all_to_all can fly while chunk k-1 combines locally.  Two is
# the minimum that overlaps at all — exactly one exchange outstanding,
# matching the two-slot buffer — and each extra chunk deepens the
# pipeline at the price of another collective launch + kernel dispatch
# per join, which only pays off once collectives are asynchronous.
DEFAULT_PIPELINE_CHUNKS = 2


def broadcast_plan_kinds(backend: str, use_mirroring: bool = True) -> tuple:
    """The message plans the executor must pre-build (per device) for one
    ``channels.broadcast`` configuration — channel-layer knowledge kept in
    one place so the algorithms can't drift."""
    if backend != "pallas":
        return ()
    return ("eg", "mir") if use_mirroring else ("all",)


def _normalize_devices(devices):
    """``devices`` is an int (1-D worker mesh, today's executor) or an
    ``(hosts, per_host)`` pair (2-D hierarchical mesh).  Returns
    ``(D, hier)`` with ``hier`` either None or the ``(H, T)`` tuple —
    note (1, 8) and (8, 1) still select the hierarchical code paths
    (one axis is just size 1), which is exactly what the parity matrix
    exploits."""
    if isinstance(devices, (tuple, list)):
        H, T = int(devices[0]), int(devices[1])
        if H < 1 or T < 1:
            raise ValueError(f"bad (hosts, devices) mesh {devices!r}")
        return H * T, (H, T)
    return int(devices), None


def graph_mesh(devices):
    """Worker mesh: 1-D over ``devices`` devices, or the 2-D
    ``(hosts, per_host)`` mesh when a pair is given."""
    D, hier = _normalize_devices(devices)
    if D > len(jax.devices()):
        raise RuntimeError(
            f"requested {D} devices but only {len(jax.devices())} "
            f"are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={D} before "
            f"importing jax (graph_run --devices does this for you)")
    if hier is not None:
        return meshlib.graph_mesh(*hier)
    return meshlib.make_mesh((D,), (AXIS,))


def _pad8(x: int) -> int:
    return max(8, -(-int(x) // 8) * 8)


def _cap_for(L: int, D: int, hint: Optional[int] = None) -> int:
    """Per-destination-device lane cap of one routed-exchange round.

    ``ceil(L/D)`` is exact for balanced traffic (one round); a hot
    destination just takes extra rounds.  ``hint`` — a static bound on the
    worst per-device-pair traffic (``PartitionedGraph.pair_counts``) —
    widens the cap up to 4x so statically-known skew still lands in one
    round without unbounding the (D, cap) buffer."""
    base = -(-L // D)
    cap = base if hint is None else max(base, min(int(hint), 4 * base))
    return min(_pad8(cap), _pad8(L))


# ---------------------------------------------------------------------------
# per-device plan stacking (pallas backend)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TracedPlan:
    """Device-local view of one per-device edge plan inside ``shard_map``.

    Row/segment counts are padded to the maximum across devices; dummy rows
    have ``row_valid`` all-False (they combine to identity and scatter into
    segment 0 harmlessly) and dummy segments are excluded from the exchange
    index lists, so they never contribute to inboxes or message counts.

    ``xseg``/``xval`` index MY segments per destination device (send side);
    ``rblk``/``rval`` give, per source device, the local destination block
    of each segment routed to me (receive side) — both built statically at
    stack time, so the all_to_all caps are exact.

    When the pipeline is on, the exchange is additionally blocked into
    ``n_chunks`` position-chunks of the xcap axis (same chunking on both
    sides of the all_to_all, so the pair caps stay exact).  Per chunk the
    tables list the rows feeding its segments (``crow``, chunk-local
    ``crow_seg`` remap) and the chunk-local exchange indices
    (``cxseg``/``cxval`` send, ``crblk``/``crval`` receive), so one
    chunk's rows can run ``segment_combine_blocks`` independently while
    another chunk's all_to_all is in flight.

    On a 2-D (host, device) mesh the exchange instead runs in two legs
    with an intermediate combine (the hierarchical tables below): leg 1
    routes my segments to the *column* of their destination device
    within my host (``x1seg``/``x1val``, all_to_all over the intra-host
    axis); the column device combines everything it received by global
    destination block (``iscat``/``ival`` -> ``n_iseg`` intermediate
    segments — the per-level Theorem-1 combine); leg 2 routes only the
    combined residue across the host axis (``x2seg``/``x2val`` send,
    ``r2blk``/``r2val`` receive at the owner).  With the pipeline on,
    the inter-host leg is position-chunked into ``hchunks`` static
    slices of the x2cap axis (where the overlap win actually lives)."""
    nb: int
    eb: int
    B_per_w: int
    n_blocks: int
    n_rows: int                # padded maximum
    n_segs: int                # padded maximum
    xcap: int                  # max segments routed between one device pair
    row_gather: jnp.ndarray    # (n_rows, eb) -> local flat edge index
    row_valid: jnp.ndarray     # (n_rows, eb)
    row_local: jnp.ndarray     # (n_rows, eb)
    row_seg: jnp.ndarray       # (n_rows,)
    seg_blk: jnp.ndarray       # (n_segs,) global block id
    seg_worker: jnp.ndarray    # (n_segs,) global source worker
    xseg: jnp.ndarray          # (D, xcap) my segment index per dest device
    xval: jnp.ndarray          # (D, xcap)
    rblk: jnp.ndarray          # (D, xcap) local dst block per source device
    rval: jnp.ndarray          # (D, xcap)
    # pipeline chunk tables (None when the pipeline is off):
    n_chunks: int = 1
    ccap: int = 0                          # exchange lanes per chunk
    cr: int = 0                            # max rows per chunk
    cs: int = 0                            # max segments per chunk
    crow: Optional[jnp.ndarray] = None     # (C, cr) row index
    crow_ok: Optional[jnp.ndarray] = None  # (C, cr)
    crow_seg: Optional[jnp.ndarray] = None  # (C, cr) chunk-local segment
    cxseg: Optional[jnp.ndarray] = None    # (C, D, ccap) chunk-local send
    cxval: Optional[jnp.ndarray] = None    # (C, D, ccap)
    crblk: Optional[jnp.ndarray] = None    # (C, D, ccap) local dst block
    crval: Optional[jnp.ndarray] = None    # (C, D, ccap)
    # hierarchical 2-D exchange tables (None on a 1-D mesh):
    x1cap: int = 0
    n_iseg: int = 0            # intermediate combined segments per device
    x2cap: int = 0
    hchunks: int = 1           # inter-host pipeline chunks
    x1seg: Optional[jnp.ndarray] = None    # (T, x1cap) my seg per dst col
    x1val: Optional[jnp.ndarray] = None    # (T, x1cap)
    iscat: Optional[jnp.ndarray] = None    # (T, x1cap) recv -> inter seg
    ival: Optional[jnp.ndarray] = None     # (T, x1cap)
    x2seg: Optional[jnp.ndarray] = None    # (H, x2cap) inter seg per host
    x2val: Optional[jnp.ndarray] = None    # (H, x2cap)
    r2blk: Optional[jnp.ndarray] = None    # (H, x2cap) local dst block
    r2val: Optional[jnp.ndarray] = None    # (H, x2cap)


def _device_plans(pg, D: int, kind: str, nb: int):
    """One EdgePlan per device covering that device's workers' edges, with
    *global* source-worker ids in ``seg_worker`` (message accounting) and
    *global* destination blocks (the exchange address space).  For a split
    partition the device slices follow the physical-shard bounds and
    ``seg_worker`` holds shard ids (combining granularity)."""
    M, n_loc = pg.M, pg.n_loc
    m = M // D
    split = _is_split(pg)
    dbounds = device_edge_bounds(pg, D) if split else None

    def build(d, eb):
        if pg.layout == "csr":
            M_src = pg.M_phys if split else M
            if kind in ("eg", "all"):
                src = np.asarray(pg.eg_src if kind == "eg" else pg.all_src)
                dst = np.asarray(pg.eg_dst if kind == "eg" else pg.all_dst)
                if split:
                    s, e = int(dbounds[kind][d]), int(dbounds[kind][d + 1])
                    pw = np.asarray(pg.eg_pw if kind == "eg"
                                    else pg.all_pw)
                    sw = pw[s:e]
                else:
                    off = pg.eg_off if kind == "eg" else pg.all_off
                    s, e = int(off[d * m]), int(off[(d + 1) * m])
                    sw = src[s:e] // n_loc
                return planlib.build_edge_plan_flat(
                    sw, dst[s:e] // n_loc, dst[s:e] % n_loc,
                    M_src, M, n_loc, nb, eb)
            edst = np.asarray(pg.mir_edst)
            if split:
                s, e = int(dbounds["mir"][d]), int(dbounds["mir"][d + 1])
                sw = np.asarray(pg.mir_pw)[s:e]
            else:
                s, e = int(pg.mir_eoff[d * m]), int(pg.mir_eoff[(d + 1) * m])
                sw = edst[s:e] // n_loc
            return planlib.build_edge_plan_flat(
                sw, edst[s:e] // n_loc, edst[s:e] % n_loc,
                M_src, M, n_loc, nb, eb)
        sl = slice(d * m, (d + 1) * m)
        if kind in ("eg", "all"):
            dst = np.asarray(pg.eg_dst if kind == "eg" else pg.all_dst)[sl]
            mask = np.asarray(pg.eg_mask if kind == "eg"
                              else pg.all_mask)[sl]
            p = planlib.build_edge_plan(dst // n_loc, dst % n_loc, mask,
                                        M, n_loc, nb, eb)
        else:
            edst = np.asarray(pg.mir_edst)[sl]
            own = np.broadcast_to(np.arange(d * m, (d + 1) * m)[:, None],
                                  edst.shape)
            p = planlib.build_edge_plan(own, edst,
                                        np.asarray(pg.mir_emask)[sl],
                                        M, n_loc, nb, eb)
        # build_edge_plan derives source workers from the (local) row index
        p.seg_worker = (p.seg_worker + d * m).astype(np.int32)
        return p

    plans = [build(d, None) for d in range(D)]
    eb = max(p.eb for p in plans)
    plans = [p if p.eb == eb else build(d, eb)
             for d, p in enumerate(plans)]
    return plans


def _stack_plans(plans, m: int, chunks: Optional[int] = None,
                 hier: Optional[Tuple[int, int]] = None):
    """Pad per-device plans to common row/segment counts, build the
    per-destination-device exchange index lists, and stack everything with
    a leading device axis.  Returns (static_meta, arrays_dict).

    ``chunks`` (the pipeline) additionally blocks the xcap axis into
    position-chunks and emits, per (device, chunk), the static row subset
    feeding that chunk's segments plus chunk-local segment/exchange
    remaps — the tables :func:`_combine_with_plan_sharded` walks to
    overlap chunk k's all_to_all with chunk k±1's local combines.

    ``hier=(H, T)`` (2-D mesh) additionally builds the two-leg exchange
    tables (see :class:`TracedPlan`): per destination *column* send lists,
    the intermediate combine-by-destination-block remap, and per
    destination *host* residue lists.  The pipeline then chunks the
    inter-host leg instead of the flat xcap axis."""
    D = len(plans)
    nb, eb = plans[0].nb, plans[0].eb
    bpd = m * plans[0].B_per_w               # destination blocks per device
    R = max(1, max(p.n_rows for p in plans))
    S = max(1, max(p.n_segs for p in plans))

    # destination-device blocking of the (real, un-padded) segments: the
    # routed exchange is fully static, so the caps are exact by
    # construction and the runtime never overflows them
    pair = {}
    xcap = 1
    for d, p in enumerate(plans):
        dd = (p.seg_blk // bpd if p.n_segs
              else np.zeros(0, np.int64))
        for d2 in range(D):
            sel = np.flatnonzero(dd == d2)
            pair[(d, d2)] = sel
            xcap = max(xcap, len(sel))

    a = {
        "row_gather": np.zeros((D, R, eb), np.int32),
        "row_valid": np.zeros((D, R, eb), bool),
        "row_local": np.full((D, R, eb), -1, np.int32),
        "row_seg": np.zeros((D, R), np.int32),
        "seg_blk": np.zeros((D, S), np.int32),
        "seg_worker": np.zeros((D, S), np.int32),
        "xseg": np.zeros((D, D, xcap), np.int32),
        "xval": np.zeros((D, D, xcap), bool),
        "rblk": np.zeros((D, D, xcap), np.int32),
        "rval": np.zeros((D, D, xcap), bool),
    }
    for d, p in enumerate(plans):
        a["row_gather"][d, :p.n_rows] = p.row_gather
        a["row_valid"][d, :p.n_rows] = p.row_valid
        a["row_local"][d, :p.n_rows] = p.row_local
        a["row_seg"][d, :p.n_rows] = p.row_seg
        a["seg_blk"][d, :p.n_segs] = p.seg_blk
        a["seg_worker"][d, :p.n_segs] = p.seg_worker
    for (d, d2), sel in pair.items():
        c = len(sel)
        a["xseg"][d, d2, :c] = sel
        a["xval"][d, d2, :c] = True
        a["rblk"][d2, d, :c] = plans[d].seg_blk[sel] - d2 * bpd
        a["rval"][d2, d, :c] = True
    meta = {"nb": nb, "eb": eb, "B_per_w": plans[0].B_per_w,
            "n_blocks": plans[0].n_blocks, "n_rows": R, "n_segs": S,
            "xcap": xcap}
    if hier is not None:
        meta.update(_hier_plan_tables(plans, a, D, bpd, *hier,
                                      chunks=chunks))
    elif chunks:
        meta.update(_chunk_plans(plans, pair, a, D, bpd, xcap, chunks))
    return meta, a


def _hier_plan_tables(plans, a, D: int, bpd: int, H: int, T: int,
                      chunks: Optional[int] = None):
    """Two-leg static exchange tables for a 2-D (H, T) mesh.

    Leg 1 (intra-host, axis ``"w"``): device (h, t1) sends each real
    segment to the device of its destination *column* t2 within its own
    host.  The intermediate device (h, t2) combines everything it
    received by global destination block — two segments from different
    senders aimed at the same block merge *before* crossing the host
    axis (the Theorem-1 combine applied per level).  Leg 2 (inter-host,
    axis ``"h"``): only the combined residue travels to the owner host.
    All index lists are position-aligned across the all_to_all (lane
    (t1, j) at the receiver is lane j of sender (h, t1)), so the caps
    are exact by construction and the runtime never overflows."""
    # leg-1 send lists: my segments by destination column (ascending
    # segment order — the canonical lane order both sides agree on)
    x1list = {}
    x1cap = 1
    for d, p in enumerate(plans):
        dd = (p.seg_blk // bpd if p.n_segs else np.zeros(0, np.int64))
        for t2 in range(T):
            sel = np.flatnonzero(dd % T == t2)
            x1list[(d, t2)] = sel
            x1cap = max(x1cap, len(sel))

    # intermediate combine: per device (h, t2), the distinct destination
    # blocks among its received lanes, and each lane's remap into them
    iblocks = {}
    n_iseg = 1
    for h in range(H):
        for t2 in range(T):
            i = h * T + t2
            gbs = [plans[h * T + t1].seg_blk[x1list[(h * T + t1, t2)]]
                   for t1 in range(T)]
            allg = (np.concatenate(gbs) if gbs else np.zeros(0, np.int64))
            iblocks[i] = np.unique(allg)
            n_iseg = max(n_iseg, len(iblocks[i]))

    # leg-2 residue lists: intermediate segments by destination host
    x2list = {}
    x2cap = 1
    for i in range(D):
        dh = (iblocks[i] // bpd) // T
        for h2 in range(H):
            sel = np.flatnonzero(dh == h2)
            x2list[(i, h2)] = sel
            x2cap = max(x2cap, len(sel))

    x1seg = np.zeros((D, T, x1cap), np.int32)
    x1val = np.zeros((D, T, x1cap), bool)
    iscat = np.zeros((D, T, x1cap), np.int32)
    ival = np.zeros((D, T, x1cap), bool)
    x2seg = np.zeros((D, H, x2cap), np.int32)
    x2val = np.zeros((D, H, x2cap), bool)
    r2blk = np.zeros((D, H, x2cap), np.int32)
    r2val = np.zeros((D, H, x2cap), bool)
    for h in range(H):
        for t2 in range(T):
            i = h * T + t2
            for t1 in range(T):
                s = h * T + t1
                sel = x1list[(s, t2)]
                c = len(sel)
                x1seg[s, t2, :c] = sel
                x1val[s, t2, :c] = True
                iscat[i, t1, :c] = np.searchsorted(
                    iblocks[i], plans[s].seg_blk[sel])
                ival[i, t1, :c] = True
            for h2 in range(H):
                sel = x2list[(i, h2)]
                c = len(sel)
                o = h2 * T + t2
                x2seg[i, h2, :c] = sel
                x2val[i, h2, :c] = True
                r2blk[o, h, :c] = iblocks[i][sel] - o * bpd
                r2val[o, h, :c] = True
    a.update(x1seg=x1seg, x1val=x1val, iscat=iscat, ival=ival,
             x2seg=x2seg, x2val=x2val, r2blk=r2blk, r2val=r2val)
    return {"x1cap": x1cap, "n_iseg": n_iseg, "x2cap": x2cap,
            "hchunks": max(1, min(int(chunks or 1), x2cap))}


def _chunk_plans(plans, pair, a, D: int, bpd: int, xcap: int, chunks: int):
    """Pipeline chunk tables (see :func:`_stack_plans`).  Chunk c covers
    positions [c*ccap, (c+1)*ccap) of every pair's exchange list — the
    same position window on sender and receiver, so a chunk's all_to_all
    caps stay exact by construction.  Every real segment lands in exactly
    one chunk (its position in its destination-device list), hence every
    real row in exactly one chunk's row table: the chunks partition the
    local combine work."""
    ccap = max(1, -(-xcap // max(int(chunks), 1)))
    C = -(-xcap // ccap)

    # collect per (device, chunk): segment list (in d2-major position
    # order), row list, chunk-local remaps
    rows_dc, segs_dc = {}, {}
    for d, p in enumerate(plans):
        row_seg = p.row_seg            # sorted ascending by construction
        for c in range(C):
            seg_list = []              # (d2, j, seg) in collection order
            row_list = []
            row_cseg = []
            for d2 in range(D):
                sel = pair[(d, d2)][c * ccap:(c + 1) * ccap]
                for j, s in enumerate(sel):
                    local = len(seg_list)
                    seg_list.append((d2, j, int(s)))
                    lo = np.searchsorted(row_seg, s, "left")
                    hi = np.searchsorted(row_seg, s, "right")
                    row_list.extend(range(int(lo), int(hi)))
                    row_cseg.extend([local] * int(hi - lo))
            segs_dc[(d, c)] = seg_list
            rows_dc[(d, c)] = (row_list, row_cseg)

    CR = max(1, max(len(r) for r, _ in rows_dc.values()))
    CS = max(1, max(len(s) for s in segs_dc.values()))
    crow = np.zeros((D, C, CR), np.int32)
    crow_ok = np.zeros((D, C, CR), bool)
    crow_seg = np.zeros((D, C, CR), np.int32)
    cxseg = np.zeros((D, C, D, ccap), np.int32)
    cxval = np.zeros((D, C, D, ccap), bool)
    crblk = np.zeros((D, C, D, ccap), np.int32)
    crval = np.zeros((D, C, D, ccap), bool)
    for (d, c), (row_list, row_cseg) in rows_dc.items():
        k = len(row_list)
        crow[d, c, :k] = row_list
        crow_ok[d, c, :k] = True
        crow_seg[d, c, :k] = row_cseg
        for local, (d2, j, s) in enumerate(segs_dc[(d, c)]):
            cxseg[d, c, d2, j] = local
            cxval[d, c, d2, j] = True
            crblk[d2, c, d, j] = plans[d].seg_blk[s] - d2 * bpd
            crval[d2, c, d, j] = True
    a.update(crow=crow, crow_ok=crow_ok, crow_seg=crow_seg,
             cxseg=cxseg, cxval=cxval, crblk=crblk, crval=crval)
    return {"n_chunks": C, "ccap": ccap, "cr": CR, "cs": CS}


# ---------------------------------------------------------------------------
# static fetch plans: route known value sets owner -> consumer devices
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TracedFetch:
    """Device-local view of a static fetch plan: this device's needed
    remote/local values arrive as a compact (n_need,) array through ONE
    exchange (consumers' needed-slot lists are static, so the per-pair
    caps are exact).

    On a 2-D (host, device) mesh the plan instead runs in two legs
    through a per-host *gateway*: the owner (h_o, t) sends each value
    ONCE per consuming host — to device (h_c, t), the consuming host's
    gateway for column t (leg A, inter-host axis) — and the gateway
    fans it out to the consumers within its host (leg B, intra-host
    axis).  That is the paper's Theorem-1 mirror bound applied per
    routing level: the cross-host cost of a value is min(H, #consuming
    hosts), never #consuming devices."""
    n_need: int                # padded compact-array length
    cap: int = 0               # flat: max slots between one device pair
    send_slot: Optional[jnp.ndarray] = None  # (D, cap) LOCAL slot, -1 pad
    recv_pos: Optional[jnp.ndarray] = None   # (D, cap) compact pos, -1
    # hierarchical (2-D) tables:
    n_gw: int = 0              # gateway buffer length
    cap_a: int = 0             # max slots owner -> gateway (inter-host)
    cap_b: int = 0             # max slots gateway -> consumer (intra-host)
    a_send: Optional[jnp.ndarray] = None   # (H, cap_a) LOCAL slot, -1
    a_recv: Optional[jnp.ndarray] = None   # (H, cap_a) gateway pos, -1
    b_send: Optional[jnp.ndarray] = None   # (T, cap_b) gateway pos, -1
    b_recv: Optional[jnp.ndarray] = None   # (T, cap_b) compact pos, -1


def _build_fetch_plan(need_lists, D: int, loc_n: int,
                      hier: Optional[Tuple[int, int]] = None):
    """``need_lists``: per-device sorted unique GLOBAL slot ids (host
    numpy).  Owner of slot g is ``g // loc_n``.  Returns (meta, stacked
    host arrays) for :class:`TracedFetch` (two-leg gateway tables when
    ``hier=(H, T)``)."""
    n_need = max(1, max((len(x) for x in need_lists), default=1))
    if hier is not None:
        return _build_fetch_plan_hier(need_lists, loc_n, *hier, n_need)
    cap = 1
    pair = {}
    for d, need in enumerate(need_lists):
        need = np.asarray(need, np.int64)
        bounds = np.searchsorted(need, np.arange(D + 1) * loc_n)
        for s in range(D):
            lo, hi = int(bounds[s]), int(bounds[s + 1])
            pair[(s, d)] = (need[lo:hi], np.arange(lo, hi))
            cap = max(cap, hi - lo)
    send_slot = np.full((D, D, cap), -1, np.int32)
    recv_pos = np.full((D, D, cap), -1, np.int32)
    for (s, d), (slots, pos) in pair.items():
        c = len(slots)
        send_slot[s, d, :c] = slots - s * loc_n
        recv_pos[d, s, :c] = pos
    meta = {"cap": cap, "n_need": n_need}
    return meta, {"send_slot": send_slot, "recv_pos": recv_pos}


def _build_fetch_plan_hier(need_lists, loc_n: int, H: int, T: int,
                           n_need: int):
    """Two-leg fetch tables (see :class:`TracedFetch`).  The gateway of
    column t in host h_c is device (h_c, t): it receives, over the host
    axis, every slot owned by column-t devices that ANY device of host
    h_c needs (deduplicated per host — the per-level combine), then
    distributes within the host."""
    D = H * T
    # gateway slot sets: gw_set[(h_c, t)] = sorted unique slots needed by
    # host h_c whose owner device sits in column t
    gw_set = {}
    n_gw = 1
    for hc in range(H):
        lists = [np.asarray(need_lists[hc * T + t], np.int64)
                 for t in range(T)]
        host_need = (np.unique(np.concatenate(lists)) if lists
                     else np.zeros(0, np.int64))
        own_col = (host_need // loc_n) % T
        for to in range(T):
            gw_set[(hc, to)] = host_need[own_col == to]
            n_gw = max(n_gw, len(gw_set[(hc, to)]))

    cap_a = 1
    a_pairs = {}
    for (hc, to), s in gw_set.items():
        owner_host = s // (loc_n * T)
        bounds = np.searchsorted(owner_host, np.arange(H + 1))
        for ho in range(H):
            lo, hi = int(bounds[ho]), int(bounds[ho + 1])
            a_pairs[(ho, hc, to)] = (s[lo:hi], np.arange(lo, hi))
            cap_a = max(cap_a, hi - lo)
    cap_b = 1
    b_pairs = {}
    for hc in range(H):
        for tc in range(T):
            need = np.asarray(need_lists[hc * T + tc], np.int64)
            own_col = (need // loc_n) % T
            for to in range(T):
                sel = np.flatnonzero(own_col == to)
                gpos = np.searchsorted(gw_set[(hc, to)], need[sel])
                b_pairs[(to, tc, hc)] = (gpos, sel)
                cap_b = max(cap_b, len(sel))

    a_send = np.full((D, H, cap_a), -1, np.int32)
    a_recv = np.full((D, H, cap_a), -1, np.int32)
    for (ho, hc, to), (slots, pos) in a_pairs.items():
        c = len(slots)
        a_send[ho * T + to, hc, :c] = slots - (ho * T + to) * loc_n
        a_recv[hc * T + to, ho, :c] = pos
    b_send = np.full((D, T, cap_b), -1, np.int32)
    b_recv = np.full((D, T, cap_b), -1, np.int32)
    for (to, tc, hc), (gpos, pos) in b_pairs.items():
        c = len(gpos)
        b_send[hc * T + to, tc, :c] = gpos
        b_recv[hc * T + tc, to, :c] = pos
    meta = {"n_need": n_need, "n_gw": n_gw, "cap_a": cap_a,
            "cap_b": cap_b}
    return meta, {"a_send": a_send, "a_recv": a_recv,
                  "b_send": b_send, "b_recv": b_recv}


def _fetch_planned(sg, fp: TracedFetch, flat_vals: jnp.ndarray, fill
                   ) -> jnp.ndarray:
    """Run one static fetch plan: returns my compact (n_need,) value
    array.  ``flat_vals`` is my local (m_loc*n_loc,) owner-side array.
    On a 2-D mesh the value rides the two-leg gateway route — one
    inter-host lane per (slot, consuming host), then intra-host
    fan-out.  ``flat_vals`` may carry a trailing feature axis — the
    (lanes, F) block rides the same route (``all_to_all`` splits axis 0,
    the scatter indices address axis 0)."""
    n = flat_vals.shape[0]
    feat = planlib.feat_shape(flat_vals, 1)
    if fp.a_send is not None:
        ga = flat_vals[jnp.clip(fp.a_send, 0, n - 1)]
        send_a = jnp.where(
            planlib.feat_mask(fp.a_send >= 0, ga, fp.a_send.ndim), ga, fill)
        recv_a = jax.lax.all_to_all(send_a, HAXIS, 0, 0)
        gidx = jnp.where(fp.a_recv >= 0, fp.a_recv, fp.n_gw)
        gw = jnp.full((fp.n_gw + 1,) + feat, fill, flat_vals.dtype
                      ).at[gidx].set(recv_a)[:-1]
        gb = gw[jnp.clip(fp.b_send, 0, fp.n_gw - 1)]
        send_b = jnp.where(
            planlib.feat_mask(fp.b_send >= 0, gb, fp.b_send.ndim), gb, fill)
        recv = jax.lax.all_to_all(send_b, AXIS, 0, 0)
        idx = jnp.where(fp.b_recv >= 0, fp.b_recv, fp.n_need)
    else:
        gs = flat_vals[jnp.clip(fp.send_slot, 0, n - 1)]
        send = jnp.where(
            planlib.feat_mask(fp.send_slot >= 0, gs, fp.send_slot.ndim),
            gs, fill)
        recv = jax.lax.all_to_all(send, sg.axis, 0, 0)
        idx = jnp.where(fp.recv_pos >= 0, fp.recv_pos, fp.n_need)
    buf = jnp.full((fp.n_need + 1,) + feat, fill, flat_vals.dtype)
    return buf.at[idx].set(recv)[:-1]


# ---------------------------------------------------------------------------
# host-side graph sharding
# ---------------------------------------------------------------------------

def csr_device_bounds(off: np.ndarray, M: int, D: int) -> np.ndarray:
    """(D+1,) edge offsets at device boundaries of a (M+1,) worker csr."""
    m = M // D
    return np.asarray(off)[np.arange(0, M + 1, m)]


def _is_split(pg) -> bool:
    return getattr(pg, "phys_log", None) is not None


def device_edge_bounds(pg, devices) -> Dict[str, np.ndarray]:
    """Per-device (D+1,) edge bounds for each csr edge set (``devices``
    an int or an ``(H, T)`` pair — bounds follow the flat device order).

    Default partitions place boundaries at worker multiples (m = M/D
    workers per device).  Split partitions place them between *physical
    shards*, packed contiguously to minimize the bottleneck per-device
    eg+mir edge load (``"phys"`` holds the shard-index bounds)."""
    D, _ = _normalize_devices(devices)
    if _is_split(pg):
        loads = np.diff(pg.phys_eg_off) + np.diff(pg.phys_mir_off)
        pb = cost_model.contiguous_bounds(loads, D)
        return {"phys": pb,
                "eg": np.asarray(pg.phys_eg_off)[pb],
                "all": np.asarray(pg.phys_all_off)[pb],
                "mir": np.asarray(pg.phys_mir_off)[pb]}
    return {"phys": None,
            "eg": csr_device_bounds(pg.eg_off, pg.M, D),
            "all": csr_device_bounds(pg.all_off, pg.M, D),
            "mir": csr_device_bounds(pg.mir_eoff, pg.M, D)}


def device_edge_loads(pg, devices) -> np.ndarray:
    """(D,) per-device superstep edge load (Ch_msg + mirror fan-out) the
    mesh placement yields — the number the bench-balance gate watches."""
    b = device_edge_bounds(pg, devices)
    return np.diff(b["eg"]) + np.diff(b["mir"])


def crossness_report(pg, devices=None) -> Dict[str, float]:
    """Static locality accounting from the partition's ``pair_counts``
    matrix: the fraction of combined messages (distinct (source worker,
    destination vertex) pairs — exactly what one full-broadcast
    superstep puts on the wire) that crosses a worker, device, or host
    boundary.  This is the objective ``balance="edges+refine"``
    descends, and it is honest by construction: the cross-worker count
    equals the measured ``msgs_combined`` of a full first superstep
    with mirroring off (pinned in tests).

    Devices map to uniform worker blocks of m = M/D (the state
    sharding); a hierarchical ``(H, T)`` mesh adds host blocks of M/H.
    Split partitions pack *physical* shards onto devices, so their
    device/host rows here are the logical-block approximation.
    """
    pc = np.asarray(pg.pair_counts, np.int64)
    M = pg.M
    total = int(pc.sum())

    def _frac(cross):
        return float(cross) / total if total else 0.0

    cross_w = total - int(np.trace(pc))
    rep = {"total": total, "cross_worker": cross_w,
           "cross_worker_frac": _frac(cross_w)}
    if devices is not None:
        D, hier = _normalize_devices(devices)
        if M % D:
            raise ValueError(f"M={M} must divide over D={D} devices")
        m = M // D
        blocks = pc.reshape(D, m, D, m).sum(axis=(1, 3))
        cross_d = total - int(np.trace(blocks))
        rep.update(D=D, cross_device=cross_d,
                   cross_device_frac=_frac(cross_d))
        if hier is not None:
            H, T = hier
            hb = blocks.reshape(H, T, H, T).sum(axis=(1, 3))
            cross_h = total - int(np.trace(hb))
            rep.update(H=H, cross_host=cross_h,
                       cross_host_frac=_frac(cross_h))
    return rep


def _pad_device_slices(arr: np.ndarray, bounds: np.ndarray, pad_row):
    """Slice a flat (E,) array at ``bounds`` into (D, cap) with per-device
    padding values ``pad_row[d]``; also returns the validity mask."""
    D = len(bounds) - 1
    counts = np.diff(bounds)
    cap = max(1, int(counts.max()))
    out = np.empty((D, cap), arr.dtype)
    valid = np.zeros((D, cap), bool)
    for d in range(D):
        c = int(counts[d])
        out[d, :c] = arr[bounds[d]:bounds[d + 1]]
        out[d, c:] = pad_row[d]
        valid[d, :c] = True
    return out, valid


def _cap_hint(pg, D: int) -> Optional[int]:
    """Static per-device-pair distinct-target bound from the partition's
    (M, M) worker-pair message-count matrix — the initial cap the routed
    edge-shaped exchanges use (None when unavailable, e.g. split bounds
    don't align with worker blocks)."""
    pc = getattr(pg, "pair_counts", None)
    if pc is None or _is_split(pg):
        return None
    m = pg.M // D
    blocks = pc.reshape(D, m, D, m).sum(axis=(1, 3))
    return int(blocks.max())


def _cap_hints_2d(pg, D: int, H: int, T: int
                  ) -> Tuple[Optional[int], Optional[int]]:
    """Level-aware cap hints for the 2-D mesh — the flat per-device-pair
    bound silently under-caps a hierarchical exchange (a column device
    funnels a whole host's traffic to T columns, and an intermediate
    device funnels T senders' residue to H hosts), so each leg gets its
    own bound from ``pair_counts``:

    * intra-host leg: worst (source device, destination column) traffic
      — destination hosts folded together;
    * inter-host leg: worst (source host, destination host, column)
      traffic — the pre-combine bound on the residue an intermediate
      device can route to one host (the combine only shrinks it).
    """
    pc = getattr(pg, "pair_counts", None)
    if pc is None or _is_split(pg):
        return None, None
    m = pg.M // D
    blocks = pc.reshape(D, m, D, m).sum(axis=(1, 3))
    hint_w = int(blocks.reshape(D, H, T).sum(axis=1).max())
    hint_h = int(blocks.reshape(H, T, H, T).sum(axis=1).max())
    return hint_w, hint_h


def _shard_graph(pg, devices, plan_kinds: Sequence[str],
                 pipeline: bool = False,
                 pipeline_chunks: Optional[int] = None):
    """Build the device-stacked array pytree + matching PartitionSpecs.
    ``devices`` is an int (1-D mesh) or an ``(H, T)`` pair (2-D
    hierarchical mesh; the flat device order d = h*T + t matches the
    row-major mesh flattening, so every flat table below stays valid)."""
    D, hier = _normalize_devices(devices)
    M, n_loc = pg.M, pg.n_loc
    m = M // D
    loc_n = m * n_loc
    split = _is_split(pg)
    # chunking exists to overlap the collective with the local combine;
    # on a 1-device mesh the all_to_all is a local transpose, so the
    # extra kernel dispatches would be pure overhead — default the chunk
    # count to 1 there (an explicit pipeline_chunks still forces it)
    chunks = ((pipeline_chunks
               or (DEFAULT_PIPELINE_CHUNKS if D > 1 else 1))
              if pipeline else None)
    arrays: Dict = {"vmask": pg.vmask, "deg": pg.deg,
                    "mir_ids": pg.mir_ids, "mir_nworkers": pg.mir_nworkers}
    specs: Dict = {"vmask": P(AXIS), "deg": P(AXIS),
                   "mir_ids": P(), "mir_nworkers": P()}
    hint_w, hint_h = _cap_hints_2d(pg, D, *hier) if hier else (None, None)
    meta = {"M": M, "n_loc": n_loc, "D": D, "m_loc": m, "n": pg.n,
            "tau": pg.tau, "layout": pg.layout, "split": split,
            "hier": hier, "cap_hint": _cap_hint(pg, D),
            "cap_hint_w": hint_w, "cap_hint_h": hint_h, "plan_meta": {},
            "fetch_meta": {}, "pipeline": pipeline,
            "pipeline_chunks": chunks or 1}

    def add_fetch(name, need_lists):
        fmeta, farr = _build_fetch_plan(need_lists, D, loc_n, hier=hier)
        meta["fetch_meta"][name] = fmeta
        for k, v in farr.items():
            arrays[f"fetch_{name}_{k}"] = v
            specs[f"fetch_{name}_{k}"] = P(AXIS)

    if pg.layout == "csr":
        dbounds = device_edge_bounds(pg, D) if split else None
        if split:
            pb = dbounds["phys"]
            meta["M_phys"] = pg.M_phys
            meta["p_bounds"] = pb
            meta["P_loc"] = int(np.diff(pb).max())
            meta["device_edge_load"] = device_edge_loads(pg, D)
            arrays["phys_log"] = jnp.asarray(pg.phys_log, jnp.int32)
            specs["phys_log"] = P()
        base = np.arange(D) * m * n_loc        # a safe in-range pad id
        for name, off_name in (("eg", "eg_off"), ("all", "all_off")):
            off = (dbounds[name] if split
                   else csr_device_bounds(getattr(pg, off_name), M, D))
            src, vs = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_src")), off, base)
            dst, _ = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_dst")), off, np.zeros(D))
            w, _ = _pad_device_slices(
                np.asarray(getattr(pg, f"{name}_w")), off, np.zeros(D))
            arrays[f"{name}_src"] = src
            arrays[f"{name}_dst"] = dst
            arrays[f"{name}_w"] = w
            arrays[f"{name}_mask"] = vs
            specs.update({f"{name}_src": P(AXIS), f"{name}_dst": P(AXIS),
                          f"{name}_w": P(AXIS), f"{name}_mask": P(AXIS)})
            if split:
                pw, _ = _pad_device_slices(
                    np.asarray(getattr(pg, f"{name}_pw")), off, pb[:-1])
                arrays[f"{name}_pw"] = pw
                specs[f"{name}_pw"] = P(AXIS)
                # split device bounds cross worker state blocks: build the
                # static source-value fetch plan + compact per-edge index
                # (the padded src rows reuse base[d], a real slot, so pad
                # lanes simply share a fetched value and stay masked)
                need = [np.unique(src[d]) for d in range(D)]
                add_fetch(name, need)
                csrc = np.stack([
                    np.searchsorted(need[d], src[d]).astype(np.int32)
                    for d in range(D)])
                arrays[f"{name}_csrc"] = csrc
                specs[f"{name}_csrc"] = P(AXIS)
        off = (dbounds["mir"] if split
               else csr_device_bounds(pg.mir_eoff, M, D))
        esrc, vs = _pad_device_slices(np.asarray(pg.mir_esrc), off,
                                      np.zeros(D))
        edst, _ = _pad_device_slices(np.asarray(pg.mir_edst), off, base)
        ew, _ = _pad_device_slices(np.asarray(pg.mir_ew), off, np.zeros(D))
        arrays.update(mir_esrc=esrc, mir_edst=edst, mir_ew=ew, mir_emask=vs)
        specs.update(mir_esrc=P(AXIS), mir_edst=P(AXIS), mir_ew=P(AXIS),
                     mir_emask=P(AXIS))
        if split:
            pw, _ = _pad_device_slices(np.asarray(pg.mir_pw), off, pb[:-1])
            arrays["mir_pw"] = pw
            specs["mir_pw"] = P(AXIS)
    else:
        for name in ("eg_src", "eg_dst", "eg_mask", "eg_w",
                     "all_src", "all_dst", "all_mask", "all_w",
                     "mir_esrc", "mir_edst", "mir_emask", "mir_ew"):
            arrays[name] = getattr(pg, name)
            specs[name] = P(AXIS)

    # mirror-value fetch plan: each device needs the state slots of the
    # mirrored vertices referenced by ITS mirror edges (static)
    mir_ids_np = np.asarray(pg.mir_ids, np.int64)
    n_pad = M * n_loc
    esrc_np = np.asarray(arrays["mir_esrc"])
    emask_np = np.asarray(arrays["mir_emask"])
    if pg.layout != "csr":
        mm = M // D
        esrc_np = esrc_np.reshape(D, mm * esrc_np.shape[1])
        emask_np = emask_np.reshape(D, mm * emask_np.shape[1])
    need_lists, cesrc = [], []
    for d in range(D):
        safe = np.clip(esrc_np[d], 0, len(mir_ids_np) - 1)
        gids = mir_ids_np[safe]
        ok = emask_np[d] & (gids < n_pad)
        need = np.unique(gids[ok]) if ok.any() else np.zeros(0, np.int64)
        need_lists.append(need)
        pos = (np.searchsorted(need, gids) if len(need)
               else np.zeros(len(gids), np.int64))
        pos = np.where(ok, np.clip(pos, 0, max(len(need) - 1, 0)), 0)
        cesrc.append(pos.astype(np.int32))
    add_fetch("mir", need_lists)
    arrays["mir_cesrc"] = np.stack(cesrc)
    specs["mir_cesrc"] = P(AXIS)

    for kind in plan_kinds:
        pmeta, parrs = _stack_plans(
            _device_plans(pg, D, kind, planlib.default_nb()), m,
            chunks=chunks, hier=hier)
        meta["plan_meta"][kind] = pmeta
        for k, v in parrs.items():
            arrays[f"plan_{kind}_{k}"] = v
            specs[f"plan_{kind}_{k}"] = P(AXIS)
    if hier:
        # device-stacked leading axes shard over BOTH mesh axes (the
        # flat device order d = h*T + t IS the row-major (h, w) order)
        both = P((HAXIS, AXIS))
        specs = {k: (both if v == P(AXIS) else v)
                 for k, v in specs.items()}
    return meta, arrays, specs


# ---------------------------------------------------------------------------
# frozen shape profiles: resident executors that NEVER re-trace
# ---------------------------------------------------------------------------
#
# jax.jit caches on (function object, argument shapes/dtypes).  A resident
# program built by ``build_sharded`` keeps its function object alive, so
# the only way a graph mutation can force a re-trace is by changing the
# shapes of the ``arrays`` pytree — per-device edge caps, the mirror-id
# table length, the mirror fetch-plan tables — or a meta static like the
# pair_counts cap hint.  A ShardProfile freezes every one of those at
# warmup (with headroom), and ``reshard_arrays`` re-pads a folded graph's
# arrays to the exact same envelope: same function + same shapes = cache
# hit, zero re-traces, while an overflow past the envelope raises
# ``ProfileOverflow`` so the caller re-warms deliberately.  Padding is
# semantics-free by the masking contract (mask=False lanes contribute
# nothing to values or stats), and a frozen cap hint can only change how
# many overflow *rounds* a routed exchange takes — never its result.

class ProfileOverflow(ValueError):
    """The graph outgrew its frozen ShardProfile: re-warm the executor."""


@dataclasses.dataclass(frozen=True)
class ShardProfile:
    """Frozen shape envelope of a resident sharded executor (csr layout,
    1-D mesh, no split, no pallas plan tables)."""
    D: int
    eg_cap: int        # per-device Ch_msg edge rows
    all_cap: int       # per-device full-adjacency rows
    mir_cap: int       # per-device mirror fan-out rows
    n_mir: int         # replicated mirror-id table length
    fetch_cap: int     # mirror fetch plan per-device-pair lanes
    fetch_need: int    # mirror fetch plan compact buffer length
    cap_hint: Optional[int]  # frozen pair_counts routing cap


def _profile_supported(meta):
    if meta["layout"] != "csr":
        raise ValueError("ShardProfile needs layout='csr' (padded shapes "
                         "are already content-dependent per worker)")
    if meta["split"]:
        raise ValueError("ShardProfile does not support balance='split': "
                         "physical shard bounds are static meta, not "
                         "paddable arrays")
    if meta["hier"]:
        raise ValueError("ShardProfile supports the 1-D mesh only")
    if meta["plan_meta"]:
        raise ValueError("ShardProfile supports plan_kinds=() (dense "
                         "backend) only")


def shard_profile(pg, devices, slack: float = 1.25,
                  pad: int = 8) -> ShardProfile:
    """Measure ``pg``'s natural shard shapes and inflate them by
    ``slack`` (rounded up to ``pad`` lanes) into a frozen envelope with
    mutation headroom."""
    D, _ = _normalize_devices(devices)
    meta, arrays, _ = _shard_graph(pg, devices, ())
    _profile_supported(meta)

    def up(x):
        return int(-(-int(np.ceil(x * slack)) // pad) * pad)

    fm = meta["fetch_meta"]["mir"]
    hint = meta["cap_hint"]
    return ShardProfile(
        D=D,
        eg_cap=up(arrays["eg_src"].shape[1]),
        all_cap=up(arrays["all_src"].shape[1]),
        mir_cap=up(arrays["mir_esrc"].shape[1]),
        n_mir=up(arrays["mir_ids"].shape[0]),
        fetch_cap=up(fm["cap"]), fetch_need=up(fm["n_need"]),
        cap_hint=None if hint is None else up(hint))


def _pad_cols(a, cap, pad_col, what):
    """(D, c) -> (D, cap) padded with the per-device column ``pad_col``."""
    a = np.asarray(a)
    d, c = a.shape
    if c > cap:
        raise ProfileOverflow(f"{what}: {c} rows exceed the frozen "
                              f"profile cap {cap}")
    if c == cap:
        return a
    pad = np.broadcast_to(np.asarray(pad_col, a.dtype).reshape(d, 1),
                          (d, cap - c)).copy()
    return np.concatenate([a, pad], axis=1)


def _apply_profile(meta, arrays, prof: ShardProfile) -> None:
    """Re-pad freshly sharded ``arrays`` (and the content-dependent meta
    statics) to the frozen envelope, in place."""
    _profile_supported(meta)
    D, m, n_loc = meta["D"], meta["m_loc"], meta["n_loc"]
    if D != prof.D:
        raise ProfileOverflow(f"profile built for D={prof.D}, got D={D}")
    base = np.arange(D) * m * n_loc
    zero = np.zeros(D)
    for name, cap in (("eg", prof.eg_cap), ("all", prof.all_cap)):
        arrays[f"{name}_src"] = _pad_cols(arrays[f"{name}_src"], cap,
                                          base, f"{name}_src")
        arrays[f"{name}_dst"] = _pad_cols(arrays[f"{name}_dst"], cap,
                                          zero, f"{name}_dst")
        arrays[f"{name}_w"] = _pad_cols(arrays[f"{name}_w"], cap, zero,
                                        f"{name}_w")
        arrays[f"{name}_mask"] = _pad_cols(arrays[f"{name}_mask"], cap,
                                           zero, f"{name}_mask")
    arrays["mir_esrc"] = _pad_cols(arrays["mir_esrc"], prof.mir_cap,
                                   zero, "mir_esrc")
    arrays["mir_edst"] = _pad_cols(arrays["mir_edst"], prof.mir_cap,
                                   base, "mir_edst")
    arrays["mir_ew"] = _pad_cols(arrays["mir_ew"], prof.mir_cap, zero,
                                 "mir_ew")
    arrays["mir_emask"] = _pad_cols(arrays["mir_emask"], prof.mir_cap,
                                    zero, "mir_emask")
    arrays["mir_cesrc"] = _pad_cols(arrays["mir_cesrc"], prof.mir_cap,
                                    zero, "mir_cesrc")
    # replicated mirror tables: sentinel-padded ids (n_pad => inert in
    # every need-list and value gather), zero extra workers
    ids = np.asarray(arrays["mir_ids"])
    if len(ids) > prof.n_mir:
        raise ProfileOverflow(f"n_mir {len(ids)} exceeds the frozen "
                              f"profile {prof.n_mir}")
    sent = np.full(prof.n_mir - len(ids), meta["M"] * n_loc, ids.dtype)
    arrays["mir_ids"] = np.concatenate([ids, sent])
    nw = np.asarray(arrays["mir_nworkers"])
    arrays["mir_nworkers"] = np.concatenate(
        [nw, np.zeros(prof.n_mir - len(nw), nw.dtype)])
    # mirror fetch plan: -1 lanes are dropped by _fetch_planned; a larger
    # n_need only grows the compact buffer (real positions untouched)
    fm = meta["fetch_meta"]["mir"]
    if fm["cap"] > prof.fetch_cap or fm["n_need"] > prof.fetch_need:
        raise ProfileOverflow(
            f"mirror fetch plan (cap {fm['cap']}, n_need {fm['n_need']}) "
            f"exceeds the frozen profile (cap {prof.fetch_cap}, n_need "
            f"{prof.fetch_need})")
    for k in ("send_slot", "recv_pos"):
        a = np.asarray(arrays[f"fetch_mir_{k}"])
        out = np.full(a.shape[:2] + (prof.fetch_cap,), -1, a.dtype)
        out[:, :, :a.shape[2]] = a
        arrays[f"fetch_mir_{k}"] = out
    meta["fetch_meta"]["mir"] = {"cap": prof.fetch_cap,
                                 "n_need": prof.fetch_need}
    meta["cap_hint"] = prof.cap_hint


def reshard_arrays(pg, devices, profile: ShardProfile) -> Dict:
    """Arrays-only reshard of a (folded) graph under a frozen profile:
    feed the result to a program previously built with the SAME profile —
    shapes are envelope-stable, so the jit cache hits (zero re-trace)."""
    meta, arrays, _ = _shard_graph(pg, devices, ())
    _apply_profile(meta, arrays, profile)
    return arrays


# ---------------------------------------------------------------------------
# the inside-shard_map graph view
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedGraph:
    """Device-local twin of PartitionedGraph inside the ``shard_map`` body.

    Duck-types the fields algorithms and channels read — ``M``/``n_loc``
    stay *global* (owner arithmetic, per-worker stats), edge/vertex arrays
    are the local shard, and the ``g*`` reductions become collectives.
    ``channels.broadcast`` & friends detect the ``axis`` attribute and
    route to the sharded implementations below."""
    M: int
    n_loc: int
    m_loc: int
    D: int
    n: int
    tau: int
    layout: str
    axis: object               # "w", or ("h", "w") on a 2-D mesh
    w0: jnp.ndarray            # global index of this device's first worker
    vmask: jnp.ndarray
    deg: jnp.ndarray
    eg_src: jnp.ndarray
    eg_dst: jnp.ndarray
    eg_mask: jnp.ndarray
    eg_w: jnp.ndarray
    all_src: jnp.ndarray
    all_dst: jnp.ndarray
    all_mask: jnp.ndarray
    all_w: jnp.ndarray
    mir_ids: jnp.ndarray
    mir_nworkers: jnp.ndarray
    mir_esrc: jnp.ndarray
    mir_edst: jnp.ndarray
    mir_emask: jnp.ndarray
    mir_ew: jnp.ndarray
    mir_cesrc: jnp.ndarray     # mirror edge -> index into the fetched values
    plans: Dict[str, TracedPlan] = dataclasses.field(default_factory=dict)
    fetch: Dict[str, TracedFetch] = dataclasses.field(default_factory=dict)
    cap_hint: Optional[int] = None
    # 2-D (host, device) mesh: T > 0 selects the hierarchical exchanges
    # (flat device d = h*T + t; intra-host axis "w" size T, host axis "h"
    # size H) with per-level cap hints replacing the flat one
    H: int = 1
    T: int = 0
    cap_hint_w: Optional[int] = None
    cap_hint_h: Optional[int] = None
    # double-buffered pipeline: chunk each routed exchange so chunk k's
    # all_to_all overlaps chunk k-1's local combine (results stay exact;
    # see _routed_scatter_combine / _combine_with_plan_sharded)
    pipeline: bool = False
    pipeline_chunks: int = 1
    # split partitions (physical shards as the device placement unit):
    split: bool = False
    M_phys: int = 0
    P_loc: int = 0                      # max shards per device
    p0: Optional[jnp.ndarray] = None    # first shard id of this device
    phys_log: Optional[jnp.ndarray] = None   # replicated (M_phys,)
    eg_pw: Optional[jnp.ndarray] = None      # device-local per-edge shards
    all_pw: Optional[jnp.ndarray] = None
    mir_pw: Optional[jnp.ndarray] = None
    eg_csrc: Optional[jnp.ndarray] = None    # edge -> fetched-source index
    all_csrc: Optional[jnp.ndarray] = None

    @property
    def n_pad(self) -> int:
        return self.M * self.n_loc

    @property
    def hier(self) -> bool:
        return self.T > 0

    def log_of(self, worker: jnp.ndarray) -> jnp.ndarray:
        """Physical shard ids -> logical worker ids (identity when the
        partition is not split)."""
        return self.phys_log[worker] if self.split else worker

    def local_ids(self) -> jnp.ndarray:
        return ((self.w0 + jnp.arange(self.m_loc))[:, None] * self.n_loc
                + jnp.arange(self.n_loc)[None, :])

    def worker_ids(self) -> jnp.ndarray:
        """(m_loc,) global worker indices of the local rows."""
        return self.w0 + jnp.arange(self.m_loc)

    def gany(self, x):
        return jax.lax.psum(jnp.any(x).astype(jnp.int32), self.axis) > 0

    def gall(self, x):
        return jax.lax.psum((~jnp.all(x)).astype(jnp.int32), self.axis) == 0

    def gsum(self, x):
        return jax.lax.psum(jnp.sum(x), self.axis)

    def gmax(self, x):
        return jax.lax.pmax(jnp.max(x), self.axis)

    def edge_src_values(self, state, src):
        if self.layout == "csr":
            if self.split:
                # split device bounds cross state blocks: read through the
                # static source fetch plan of the matching edge set
                if src is self.all_src:
                    fp, csrc = self.fetch["all"], self.all_csrc
                elif src is self.eg_src:
                    fp, csrc = self.fetch["eg"], self.eg_csrc
                else:
                    raise ValueError(
                        "split edge_src_values needs a planned edge set "
                        "(pass sg.all_src or sg.eg_src)")
                flat = state.reshape(-1)
                return _fetch_planned(self, fp, flat,
                                      jnp.zeros((), flat.dtype))[csrc]
            return state.reshape(-1)[src - self.w0 * self.n_loc]
        return state[jnp.arange(self.m_loc)[:, None], src]


def _make_sg(meta, a) -> ShardedGraph:
    layout = meta["layout"]
    m = meta["m_loc"]
    hier = meta.get("hier")
    if hier:
        H, T = hier
        axis = (HAXIS, AXIS)
    else:
        H, T = 1, 0
        axis = AXIS
    # on the 2-D mesh the tuple index IS the flat row-major device id
    # d = h*T + t, so all flat-id arithmetic (w0, owner checks) holds
    d = jax.lax.axis_index(axis).astype(jnp.int32)
    w0 = d * m

    def loc(name):
        # csr edge leaves arrive as (1, cap) device rows; padded rows as
        # (m, ...) shards
        x = a[name]
        if layout == "csr" and name.split("_")[0] in ("eg", "all", "mir") \
                and name not in ("mir_ids", "mir_nworkers"):
            return x[0]
        return x

    plans = {}
    for kind, pm in meta["plan_meta"].items():
        chunked = {}
        if "n_chunks" in pm:
            chunked = dict(
                n_chunks=pm["n_chunks"], ccap=pm["ccap"],
                cr=pm["cr"], cs=pm["cs"],
                crow=a[f"plan_{kind}_crow"][0],
                crow_ok=a[f"plan_{kind}_crow_ok"][0],
                crow_seg=a[f"plan_{kind}_crow_seg"][0],
                cxseg=a[f"plan_{kind}_cxseg"][0],
                cxval=a[f"plan_{kind}_cxval"][0],
                crblk=a[f"plan_{kind}_crblk"][0],
                crval=a[f"plan_{kind}_crval"][0])
        if "x1cap" in pm:
            chunked.update(
                x1cap=pm["x1cap"], n_iseg=pm["n_iseg"],
                x2cap=pm["x2cap"], hchunks=pm["hchunks"],
                x1seg=a[f"plan_{kind}_x1seg"][0],
                x1val=a[f"plan_{kind}_x1val"][0],
                iscat=a[f"plan_{kind}_iscat"][0],
                ival=a[f"plan_{kind}_ival"][0],
                x2seg=a[f"plan_{kind}_x2seg"][0],
                x2val=a[f"plan_{kind}_x2val"][0],
                r2blk=a[f"plan_{kind}_r2blk"][0],
                r2val=a[f"plan_{kind}_r2val"][0])
        plans[kind] = TracedPlan(
            nb=pm["nb"], eb=pm["eb"], B_per_w=pm["B_per_w"],
            n_blocks=pm["n_blocks"], n_rows=pm["n_rows"],
            n_segs=pm["n_segs"], xcap=pm["xcap"],
            row_gather=a[f"plan_{kind}_row_gather"][0],
            row_valid=a[f"plan_{kind}_row_valid"][0],
            row_local=a[f"plan_{kind}_row_local"][0],
            row_seg=a[f"plan_{kind}_row_seg"][0],
            seg_blk=a[f"plan_{kind}_seg_blk"][0],
            seg_worker=a[f"plan_{kind}_seg_worker"][0],
            xseg=a[f"plan_{kind}_xseg"][0],
            xval=a[f"plan_{kind}_xval"][0],
            rblk=a[f"plan_{kind}_rblk"][0],
            rval=a[f"plan_{kind}_rval"][0], **chunked)
    fetch = {}
    for name, fm in meta["fetch_meta"].items():
        if "n_gw" in fm:
            fetch[name] = TracedFetch(
                n_need=fm["n_need"], n_gw=fm["n_gw"],
                cap_a=fm["cap_a"], cap_b=fm["cap_b"],
                a_send=a[f"fetch_{name}_a_send"][0],
                a_recv=a[f"fetch_{name}_a_recv"][0],
                b_send=a[f"fetch_{name}_b_send"][0],
                b_recv=a[f"fetch_{name}_b_recv"][0])
        else:
            fetch[name] = TracedFetch(
                n_need=fm["n_need"], cap=fm["cap"],
                send_slot=a[f"fetch_{name}_send_slot"][0],
                recv_pos=a[f"fetch_{name}_recv_pos"][0])
    split = meta.get("split", False)
    extra = {}
    if split:
        extra = dict(
            split=True, M_phys=meta["M_phys"], P_loc=meta["P_loc"],
            p0=jnp.asarray(meta["p_bounds"][:-1], jnp.int32)[d],
            phys_log=a["phys_log"], eg_pw=loc("eg_pw"),
            all_pw=loc("all_pw"), mir_pw=loc("mir_pw"),
            eg_csrc=a["eg_csrc"][0], all_csrc=a["all_csrc"][0])
    return ShardedGraph(
        M=meta["M"], n_loc=meta["n_loc"], m_loc=m, D=meta["D"],
        n=meta["n"], tau=meta["tau"], layout=layout, axis=axis, w0=w0,
        H=H, T=T, cap_hint_w=meta.get("cap_hint_w"),
        cap_hint_h=meta.get("cap_hint_h"),
        vmask=a["vmask"], deg=a["deg"],
        eg_src=loc("eg_src"), eg_dst=loc("eg_dst"),
        eg_mask=loc("eg_mask"), eg_w=loc("eg_w"),
        all_src=loc("all_src"), all_dst=loc("all_dst"),
        all_mask=loc("all_mask"), all_w=loc("all_w"),
        mir_ids=a["mir_ids"], mir_nworkers=a["mir_nworkers"],
        mir_esrc=loc("mir_esrc"), mir_edst=loc("mir_edst"),
        mir_emask=loc("mir_emask"), mir_ew=loc("mir_ew"),
        mir_cesrc=a["mir_cesrc"][0],
        plans=plans, fetch=fetch, cap_hint=meta.get("cap_hint"),
        pipeline=meta.get("pipeline", False),
        pipeline_chunks=meta.get("pipeline_chunks", 1), **extra)


# ---------------------------------------------------------------------------
# routed exchange cores
# ---------------------------------------------------------------------------

def _place_rows(sg: ShardedGraph, local_counts: jnp.ndarray) -> jnp.ndarray:
    """(m_loc,) per-local-worker counts -> replicated (M,) via psum."""
    full = jnp.zeros((sg.M,), local_counts.dtype)
    full = jax.lax.dynamic_update_slice(full, local_counts, (sg.w0,))
    return jax.lax.psum(full, sg.axis)


def _scatter_workers(sg: ShardedGraph, workers, flags) -> jnp.ndarray:
    """Count ``flags`` at global ``workers`` -> replicated (M,)."""
    pw = jnp.zeros((sg.M,), jnp.int32).at[
        jnp.where(flags, workers, 0)].add(flags.astype(jnp.int32))
    return jax.lax.psum(pw, sg.axis)


def _bucket_by_device(sg: ShardedGraph, targets, valid):
    """Sort lanes by destination device (invalid last).  Returns
    (order, (D+1,) bucket offsets, per-pair round count)."""
    loc_n = sg.m_loc * sg.n_loc
    dd = jnp.where(valid,
                   jnp.clip(targets, 0, sg.n_pad - 1) // loc_n,
                   sg.D).astype(jnp.int32)
    order = jnp.argsort(dd, stable=True)
    off = jnp.searchsorted(dd[order], jnp.arange(sg.D + 1, dtype=jnp.int32))
    return order, off


def _rounds_for(sg: ShardedGraph, off: jnp.ndarray, cap: int) -> jnp.ndarray:
    """Replicated number of all_to_all rounds: the psum'd overflow signal.
    Balanced traffic fits the cap in one round; a hot destination just
    adds rounds (extra cap-sized exchanges), never dropped lanes."""
    counts = off[1:] - off[:-1]
    return jax.lax.pmax(((counts + cap - 1) // cap).max(), sg.axis)


def _round_lanes(off: jnp.ndarray, r, cap: int, L: int):
    """Round ``r``'s (D, cap) lane window into the device-sorted arrays:
    per destination device the slice [off[d] + r*cap, off[d+1]) clipped to
    ``cap`` lanes.  Returns (clipped indices, in-bucket validity) — the
    indexing core both routed exchanges share."""
    idx = off[:-1, None] + r * cap + jnp.arange(cap, dtype=jnp.int32)[None]
    ok = idx < off[1:, None]
    return jnp.clip(idx, 0, L - 1), ok


def _feat_elems(feat: tuple) -> int:
    e = 1
    for s in feat:
        e *= int(s)
    return e


def _pipeline_cap(sg: ShardedGraph, cap: int, feat_elems: int = 1) -> int:
    """Shrink a routed-exchange round cap so one join spans roughly
    ``sg.pipeline_chunks`` rounds — the chunks the double buffer overlaps.
    Only ever shrinks (an explicit small test cap passes through).

    For feature-blocked payloads the cap additionally shrinks by the
    payload width: the two in-flight slots hold ``cap x F`` elements, so
    sizing the chunk in *bytes* (lanes x F) keeps the pipeline's resident
    buffer flat as F grows.  Scalar payloads (``feat_elems == 1``) take
    the original expression unchanged — the F=1 chunking, and therefore
    the pipelined parity contract, is untouched."""
    if not (sg.pipeline and sg.pipeline_chunks > 1):
        return cap
    chunks = sg.pipeline_chunks * max(1, int(feat_elems))
    return min(cap, max(8, _pad8(-(-cap // chunks))))


def _routed_scatter_combine(sg: ShardedGraph, targets, values, valid,
                            op: str, cap: Optional[int] = None
                            ) -> jnp.ndarray:
    """Destination-routed combine: (L,) lanes of (global target, value)
    pairs are bucketed by owner device, exchanged in cap-sized
    ``all_to_all`` rounds, and combined into MY local (m_loc*n_loc,)
    buffer — the per-device footprint is O(L + D*cap), never (n_pad,).

    ``sg.pipeline`` double-buffers the rounds: round r's all_to_all is
    issued before round r-1's received lanes scatter, so the collective
    flies while the combine runs.  Rounds still combine in the sequential
    order (r=0,1,...), so the result is bitwise identical."""
    if sg.hier:
        return _hier_scatter_combine(sg, targets, values, valid, op,
                                     cap=cap)
    D, loc_n = sg.D, sg.m_loc * sg.n_loc
    L = targets.shape[0]
    feat = planlib.feat_shape(values, 1)
    cap = _pipeline_cap(sg, cap or _cap_for(L, D), _feat_elems(feat))
    ident = identity_of(op, values.dtype)
    order, off = _bucket_by_device(sg, targets, valid)
    st_ = jnp.where(valid, targets, sg.n_pad)[order]
    sv_ = jnp.where(planlib.feat_mask(valid, values, 1), values,
                    ident)[order]
    rounds = _rounds_for(sg, off, cap)
    base = sg.w0 * sg.n_loc

    def _xchg(r):
        idxc, ok = _round_lanes(off, r, cap, L)
        t_send = jnp.where(ok, st_[idxc], sg.n_pad)
        sv_c = sv_[idxc]
        v_send = jnp.where(planlib.feat_mask(ok, sv_c, 2), sv_c, ident)
        return (jax.lax.all_to_all(t_send, sg.axis, 0, 0),
                jax.lax.all_to_all(v_send, sg.axis, 0, 0))

    def _combine(buf, recv):
        t_recv, v_recv = recv
        slot = t_recv - base
        okr = (slot >= 0) & (slot < loc_n)
        return scatter_op(op, buf, jnp.where(okr, slot, 0),
                          jnp.where(planlib.feat_mask(okr, v_recv, 2),
                                    v_recv, ident))

    buf0 = jnp.full((loc_n,) + feat, ident, values.dtype)
    if not sg.pipeline:
        return jax.lax.fori_loop(
            0, rounds, lambda r, buf: _combine(buf, _xchg(r)), buf0)

    def body(r, carry):
        buf, prev = carry
        cur = _xchg(r)                       # round r in flight...
        return _combine(buf, prev), cur      # ...while r-1 combines

    # prologue round 0; epilogue combines the last in-flight round.
    # rounds is replicated (pmax'd) so every device runs the same
    # collectives; rounds==0 leaves every lane masked -> buf0 unchanged.
    first = _xchg(jnp.zeros((), jnp.int32))
    buf, last = jax.lax.fori_loop(1, rounds, body, (buf0, first))
    return _combine(buf, last)


def _hier_caps(sg: ShardedGraph, L: int, cap,
               feat_elems: int = 1) -> Tuple[int, int]:
    """Per-level lane caps of one hierarchical routed exchange.  A flat
    int cap is a 1-D-mesh quantity (per-destination-*device*) and would
    silently under-cap the funnel legs here — the intra-host leg routes
    to T columns and the inter-host leg routes a whole column's residue
    to H hosts — so unless an explicit ``(cap1, cap2)`` pair is given,
    both caps are re-derived per level from the level-aware hints."""
    if isinstance(cap, tuple):
        cap1, cap2 = int(cap[0]), int(cap[1])
    else:
        cap1 = _cap_for(L, sg.T, sg.cap_hint_w)
        cap2 = _cap_for(sg.T * cap1, sg.H, sg.cap_hint_h)
    # the pipeline chunks the INTER-host leg (where the overlap pays)
    return cap1, _pipeline_cap(sg, cap2, feat_elems)


def _bucket_level(sg: ShardedGraph, targets, valid, level: str):
    """Sort lanes by the ``level`` coordinate of the destination device
    (column within host for ``"w"``, host for ``"h"``; invalid last).
    Returns (order, (K+1,) bucket offsets) with K the axis size."""
    loc_n = sg.m_loc * sg.n_loc
    dd = jnp.clip(targets, 0, sg.n_pad - 1) // loc_n
    K = sg.T if level == "w" else sg.H
    coord = dd % sg.T if level == "w" else dd // sg.T
    key = jnp.where(valid, coord, K).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    off = jnp.searchsorted(key[order], jnp.arange(K + 1, dtype=jnp.int32))
    return order, off


def _hier_scatter_combine(sg: ShardedGraph, targets, values, valid,
                          op: str, cap=None) -> jnp.ndarray:
    """2-D twin of :func:`_routed_scatter_combine`: lanes first route to
    the destination *column* within my host (axis ``"w"`` rounds), the
    column device segment-combines everything it received by target —
    the per-level Theorem-1 combine — and only the combined residue
    crosses the host axis (``"h"`` rounds) to the owner, which combines
    into its local buffer.  Round counts are pmax'd over the whole mesh
    so every device runs the same collectives; with ``sg.pipeline`` the
    inter-host rounds are double-buffered (round r's all_to_all flies
    while round r-1 scatters — the leg where the overlap win lives)."""
    H, T = sg.H, sg.T
    loc_n = sg.m_loc * sg.n_loc
    n_pad = sg.n_pad
    L = targets.shape[0]
    feat = planlib.feat_shape(values, 1)
    cap1, cap2 = _hier_caps(sg, L, cap, _feat_elems(feat))
    ident = identity_of(op, values.dtype)
    order, off = _bucket_level(sg, targets, valid, "w")
    st_ = jnp.where(valid, targets, n_pad)[order]
    sv_ = jnp.where(planlib.feat_mask(valid, values, 1), values,
                    ident)[order]
    rounds1 = _rounds_for(sg, off, cap1)
    base = sg.w0 * sg.n_loc
    L2 = T * cap1
    zerow = jnp.zeros((L2,), jnp.int32)

    def inner(buf, tf, vf):
        # intermediate combine: duplicates aimed at the same target merge
        # BEFORE crossing the host axis (worker key 0 -> key by target)
        realf, seg_t, seg_val, _, _ = planlib.sorted_segments_flat(
            tf, vf, tf < n_pad, zerow, op, n_pad)
        ord2, off2 = _bucket_level(sg, seg_t, realf, "h")
        t2_ = jnp.where(realf, seg_t, n_pad)[ord2]
        v2_ = jnp.where(planlib.feat_mask(realf, seg_val, 1), seg_val,
                        ident)[ord2]
        rounds2 = _rounds_for(sg, off2, cap2)

        def _xchg(r):
            idxc, ok = _round_lanes(off2, r, cap2, L2)
            t_send = jnp.where(ok, t2_[idxc], n_pad)
            v2_c = v2_[idxc]
            v_send = jnp.where(planlib.feat_mask(ok, v2_c, 2), v2_c,
                               ident)
            return (jax.lax.all_to_all(t_send, HAXIS, 0, 0),
                    jax.lax.all_to_all(v_send, HAXIS, 0, 0))

        def _combine(b, recv):
            t_recv, v_recv = recv
            slot = t_recv - base
            okr = (slot >= 0) & (slot < loc_n)
            return scatter_op(op, b, jnp.where(okr, slot, 0),
                              jnp.where(planlib.feat_mask(okr, v_recv, 2),
                                        v_recv, ident))

        if not sg.pipeline:
            return jax.lax.fori_loop(
                0, rounds2, lambda r, b: _combine(b, _xchg(r)), buf)

        def body(r, carry):
            b, prev = carry
            cur = _xchg(r)                   # round r in flight...
            return _combine(b, prev), cur    # ...while r-1 combines

        first = _xchg(jnp.zeros((), jnp.int32))
        buf, last = jax.lax.fori_loop(1, rounds2, body, (buf, first))
        return _combine(buf, last)

    def outer(r, buf):
        idxc, ok = _round_lanes(off, r, cap1, L)
        t_send = jnp.where(ok, st_[idxc], n_pad)       # (T, cap1)
        sv_c = sv_[idxc]
        v_send = jnp.where(planlib.feat_mask(ok, sv_c, 2), sv_c, ident)
        t_r = jax.lax.all_to_all(t_send, AXIS, 0, 0)
        v_r = jax.lax.all_to_all(v_send, AXIS, 0, 0)
        return inner(buf, t_r.reshape(-1), v_r.reshape((-1,) + feat))

    buf0 = jnp.full((loc_n,) + feat, ident, values.dtype)
    return jax.lax.fori_loop(0, rounds1, outer, buf0)


def _routed_fetch(sg: ShardedGraph, vals, targets, valid,
                  cap: Optional[int] = None) -> jnp.ndarray:
    """The request-respond transport: a real two-round trip.  (L,) global
    ``targets`` are bucketed by owner device; requests travel out in
    cap-sized ``all_to_all`` rounds, owners answer from their local
    (m_loc, n_loc) shard, responses travel back on the mirrored lanes.
    Returns (L,) gathered values, 0 where ``~valid`` (the reference
    convention for masked request lanes).

    ``sg.pipeline`` double-buffers the request rounds: request-chunk r is
    in flight (out and back) while request-chunk r-1's responses write
    into the output.  Rounds write disjoint lanes, so the result is
    bitwise identical to the sequential loop."""
    if sg.hier:
        return _hier_routed_fetch(sg, vals, targets, valid, cap=cap)
    D, loc_n = sg.D, sg.m_loc * sg.n_loc
    L = targets.shape[0]
    feat = planlib.feat_shape(vals, 2)
    cap = _pipeline_cap(sg, cap or _cap_for(L, D), _feat_elems(feat))
    flat = vals.reshape((-1,) + feat)
    zero = jnp.zeros((), vals.dtype)
    ok_t = valid & (targets >= 0) & (targets < sg.n_pad)
    order, off = _bucket_by_device(sg, targets, ok_t)
    st_ = jnp.where(ok_t, targets, sg.n_pad)[order]
    rounds = _rounds_for(sg, off, cap)
    base = sg.w0 * sg.n_loc

    def _trip(r):
        idxc, ok = _round_lanes(off, r, cap, L)
        req = jnp.where(ok, st_[idxc], sg.n_pad)
        req_r = jax.lax.all_to_all(req, sg.axis, 0, 0)
        slot = req_r - base
        okr = (slot >= 0) & (slot < loc_n)
        got_r = flat[jnp.clip(slot, 0, loc_n - 1)]
        resp = jnp.where(planlib.feat_mask(okr, got_r, 2), got_r, zero)
        return idxc, ok, jax.lax.all_to_all(resp, sg.axis, 0, 0)

    def _write(out, trip):
        idxc, ok, resp_b = trip
        return out.at[jnp.where(ok, idxc, L)].set(
            jnp.where(planlib.feat_mask(ok, resp_b, 2), resp_b, zero))

    out0 = jnp.zeros((L + 1,) + feat, vals.dtype)
    if not sg.pipeline:
        got_sorted = jax.lax.fori_loop(
            0, rounds, lambda r, out: _write(out, _trip(r)), out0)[:L]
    else:
        def body(r, carry):
            out, prev = carry
            cur = _trip(r)
            return _write(out, prev), cur

        first = _trip(jnp.zeros((), jnp.int32))
        out, last = jax.lax.fori_loop(1, rounds, body, (out0, first))
        got_sorted = _write(out, last)[:L]
    got = jnp.zeros((L,) + feat, vals.dtype).at[order].set(got_sorted)
    return jnp.where(planlib.feat_mask(ok_t, got, 1), got, zero)


def _hier_routed_fetch(sg: ShardedGraph, vals, targets, valid,
                       cap=None) -> jnp.ndarray:
    """2-D twin of :func:`_routed_fetch`: requests first route to the
    owner's *column* within my host (axis ``"w"`` rounds); the column
    device sorts the host's requests and deduplicates them — only one
    head request per distinct target crosses the host axis (Theorem 3
    applied per level) — the owner answers over the ``"h"`` trip, the
    response is propagated back down the duplicate segments, unsorted,
    and returned over the mirrored ``"w"`` lanes.  With ``sg.pipeline``
    the inter-host trips are double-buffered."""
    H, T = sg.H, sg.T
    loc_n = sg.m_loc * sg.n_loc
    n_pad = sg.n_pad
    L = targets.shape[0]
    feat = planlib.feat_shape(vals, 2)
    cap1, cap2 = _hier_caps(sg, L, cap, _feat_elems(feat))
    flat = vals.reshape((-1,) + feat)
    zero = jnp.zeros((), vals.dtype)
    ok_t = valid & (targets >= 0) & (targets < n_pad)
    order, off = _bucket_level(sg, targets, ok_t, "w")
    st_ = jnp.where(ok_t, targets, n_pad)[order]
    rounds1 = _rounds_for(sg, off, cap1)
    base = sg.w0 * sg.n_loc
    Lr = T * cap1

    def gateway(reqs):
        # host-level dedup: sort the host's requests, fetch one head per
        # distinct target over the host axis, fan the response back down
        ord2 = jnp.argsort(reqs, stable=True)
        rs = reqs[ord2]
        first = (rs < n_pad) & jnp.concatenate(
            [jnp.ones((1,), bool), rs[1:] != rs[:-1]])
        ord3, off2 = _bucket_level(sg, rs, first, "h")
        rh_ = jnp.where(first, rs, n_pad)[ord3]
        rounds2 = _rounds_for(sg, off2, cap2)

        def _trip(r):
            idxc, ok = _round_lanes(off2, r, cap2, Lr)
            req = jnp.where(ok, rh_[idxc], n_pad)
            req_r = jax.lax.all_to_all(req, HAXIS, 0, 0)
            slot = req_r - base
            okr = (slot >= 0) & (slot < loc_n)
            got_r = flat[jnp.clip(slot, 0, loc_n - 1)]
            resp = jnp.where(planlib.feat_mask(okr, got_r, 2), got_r,
                             zero)
            return idxc, ok, jax.lax.all_to_all(resp, HAXIS, 0, 0)

        def _write(out, trip):
            idxc, ok, resp_b = trip
            return out.at[jnp.where(ok, idxc, Lr)].set(
                jnp.where(planlib.feat_mask(ok, resp_b, 2), resp_b, zero))

        out0 = jnp.zeros((Lr + 1,) + feat, vals.dtype)
        if not sg.pipeline:
            head3 = jax.lax.fori_loop(
                0, rounds2, lambda r, o: _write(o, _trip(r)), out0)[:Lr]
        else:
            def body(r, carry):
                o, prev = carry
                cur = _trip(r)
                return _write(o, prev), cur

            ft = _trip(jnp.zeros((), jnp.int32))
            out, last = jax.lax.fori_loop(1, rounds2, body, (out0, ft))
            head3 = _write(out, last)[:Lr]
        heads = jnp.zeros((Lr,) + feat, vals.dtype).at[ord3].set(head3)
        hidx = jax.lax.cummax(
            jnp.where(first, jnp.arange(Lr, dtype=jnp.int32), 0))
        got = jnp.zeros((Lr,) + feat, vals.dtype).at[ord2].set(heads[hidx])
        return jnp.where(planlib.feat_mask(reqs < n_pad, got, 1), got,
                         zero)

    def outer(r, out):
        idxc, ok = _round_lanes(off, r, cap1, L)
        req = jnp.where(ok, st_[idxc], n_pad)          # (T, cap1)
        req_r = jax.lax.all_to_all(req, AXIS, 0, 0)
        got_r = gateway(req_r.reshape(-1)).reshape((T, cap1) + feat)
        resp_b = jax.lax.all_to_all(got_r, AXIS, 0, 0)
        return out.at[jnp.where(ok, idxc, L)].set(
            jnp.where(planlib.feat_mask(ok, resp_b, 2), resp_b, zero))

    out0 = jnp.zeros((L + 1,) + feat, vals.dtype)
    got_sorted = jax.lax.fori_loop(0, rounds1, outer, out0)[:L]
    got = jnp.zeros((L,) + feat, vals.dtype).at[order].set(got_sorted)
    return jnp.where(planlib.feat_mask(ok_t, got, 1), got, zero)


# ---------------------------------------------------------------------------
# sharded channel implementations
# ---------------------------------------------------------------------------

def _plan_exchange_pipelined(sg: ShardedGraph, plan: TracedPlan,
                             flat_vals: jnp.ndarray, op: str,
                             loc: jnp.ndarray, ident) -> jnp.ndarray:
    """The chunked plan exchange (see _combine_with_plan_sharded): a
    Python-unrolled double buffer over the static ``plan.n_chunks``
    chunks.  Chunk c's row subset runs the block-combine kernel and its
    segment partials are put on the wire before chunk c-1's received
    partials scatter locally."""

    feat = planlib.feat_shape(flat_vals, 1)

    def send(c):
        rows_ok = plan.crow_ok[c]
        row_out = planlib.combine_rows_subset(
            plan, flat_vals, plan.crow[c], rows_ok, op)
        sbuf = jnp.full((plan.cs, plan.nb) + feat, ident, flat_vals.dtype)
        seg_out = scatter_op(
            op, sbuf, jnp.where(rows_ok, plan.crow_seg[c], 0),
            jnp.where(planlib.feat_mask(rows_ok[:, None], row_out, 2),
                      row_out, ident))
        g = seg_out[plan.cxseg[c]]
        snd = jnp.where(planlib.feat_mask(plan.cxval[c][:, :, None], g, 3),
                        g, ident)
        return jax.lax.all_to_all(snd, sg.axis, 0, 0)

    def combine(buf, c, recv):
        return scatter_op(
            op, buf, jnp.where(plan.crval[c], plan.crblk[c], 0),
            jnp.where(planlib.feat_mask(plan.crval[c][:, :, None], recv, 3),
                      recv, ident))

    recv = send(0)
    for c in range(1, plan.n_chunks):
        nxt = send(c)                        # chunk c in flight...
        loc = combine(loc, c - 1, recv)      # ...while c-1 scatters
        recv = nxt
    return combine(loc, plan.n_chunks - 1, recv)


def _plan_exchange_hier(sg: ShardedGraph, plan: TracedPlan,
                        seg_out: jnp.ndarray, op: str,
                        loc: jnp.ndarray, ident) -> jnp.ndarray:
    """The two-leg static plan exchange (see :func:`_hier_plan_tables`):
    my segment partials ride ONE intra-host all_to_all to the device of
    their destination column, the column device op-combines everything
    it received by global destination block (``n_iseg`` compact
    intermediate segments — never an O(n) buffer), and only the combined
    residue crosses the host axis.  With the pipeline on, the inter-host
    leg is blocked into ``plan.hchunks`` static position-chunks so chunk
    c's all_to_all flies while chunk c-1's received residue scatters."""
    feat = planlib.feat_shape(seg_out, 2)
    # leg 1 (intra-host): my segments to their destination column
    g1 = seg_out[plan.x1seg]
    send1 = jnp.where(planlib.feat_mask(plan.x1val[:, :, None], g1, 3),
                      g1, ident)
    recv1 = jax.lax.all_to_all(send1, AXIS, 0, 0)      # (T, x1cap, nb)
    # intermediate combine by destination block (per-level Theorem 1)
    ibuf = jnp.full((plan.n_iseg, plan.nb) + feat, ident, seg_out.dtype)
    ibuf = scatter_op(
        op, ibuf, jnp.where(plan.ival, plan.iscat, 0),
        jnp.where(planlib.feat_mask(plan.ival[:, :, None], recv1, 3),
                  recv1, ident))

    # leg 2 (inter-host): only the combined residue crosses hosts
    def send2(sl):
        g2 = ibuf[plan.x2seg[:, sl]]
        snd = jnp.where(planlib.feat_mask(plan.x2val[:, sl, None], g2, 3),
                        g2, ident)
        return jax.lax.all_to_all(snd, HAXIS, 0, 0)

    def combine2(buf, sl, recv):
        return scatter_op(
            op, buf, jnp.where(plan.r2val[:, sl], plan.r2blk[:, sl], 0),
            jnp.where(planlib.feat_mask(plan.r2val[:, sl, None], recv, 3),
                      recv, ident))

    C = plan.hchunks if sg.pipeline else 1
    ck = -(-plan.x2cap // C)
    sls = [slice(c * ck, min((c + 1) * ck, plan.x2cap)) for c in range(C)]
    recv = send2(sls[0])
    for c in range(1, C):
        nxt = send2(sls[c])                  # chunk c in flight...
        loc = combine2(loc, sls[c - 1], recv)   # ...while c-1 scatters
        recv = nxt
    return combine2(loc, sls[-1], recv)


def _combine_with_plan_sharded(sg: ShardedGraph, plan: TracedPlan,
                               flat_vals: jnp.ndarray, op: str,
                               flat_hits: Optional[jnp.ndarray] = None,
                               count_cross: bool = True,
                               exchange: bool = True):
    """Per-device destination-blocked combine + destination-routed
    segment exchange: my (source, block) segment partials travel straight
    to the device owning their block through ONE statically-capped
    ``all_to_all``; I scatter the segments routed to me into my local
    (m_loc*B_per_w, nb) block range.  Never a global (n_blocks, nb)
    buffer, never an all-reduce over one.

    ``exchange=False`` skips the collective when the caller knows every
    segment is destination-local (the non-split mirror fan-out: mirror
    edges are destination-sharded, so self-routing them through the
    all_to_all would be a pointless per-superstep collective).

    When ``sg.pipeline`` and the plan carries chunk tables, the exchange
    is blocked into ``plan.n_chunks`` position-chunks of the xcap axis:
    chunk c's rows combine and its all_to_all is issued while chunk c-1's
    received segments scatter into ``loc`` — the double-buffered overlap.
    Rows are independent in the block-combine kernel and every real
    segment lands in exactly one chunk, so min/max/int results stay
    bitwise identical (float-sum scatter order changes within the
    tolerance the parity harness already grants sum combines)."""
    ident = identity_of(op, flat_vals.dtype)
    feat = planlib.feat_shape(flat_vals, 1)
    nbl = sg.m_loc * plan.B_per_w
    loc = jnp.full((nbl, plan.nb) + feat, ident, flat_vals.dtype)
    if exchange and sg.pipeline and plan.crow is not None \
            and plan.n_chunks > 1:
        loc = _plan_exchange_pipelined(sg, plan, flat_vals, op, loc, ident)
    else:
        gathered = flat_vals[plan.row_gather]
        packed = jnp.where(planlib.feat_mask(plan.row_valid, gathered, 2),
                           gathered, ident)
        row_out = planlib._combine_rows(packed, plan.row_local, op, plan.nb)
        seg_buf = jnp.full((plan.n_segs, plan.nb) + feat, ident,
                           flat_vals.dtype)
        seg_out = scatter_op(op, seg_buf, plan.row_seg, row_out)
        if exchange:
            if plan.x1seg is not None:
                loc = _plan_exchange_hier(sg, plan, seg_out, op, loc,
                                          ident)
            else:
                g = seg_out[plan.xseg]
                send = jnp.where(
                    planlib.feat_mask(plan.xval[:, :, None], g, 3),
                    g, ident)
                recv = jax.lax.all_to_all(send, sg.axis, 0, 0)
                loc = scatter_op(
                    op, loc, jnp.where(plan.rval, plan.rblk, 0),
                    jnp.where(
                        planlib.feat_mask(plan.rval[:, :, None], recv, 3),
                        recv, ident))
        else:
            # all segments are mine: scatter by local block id directly
            # (padded dummy segments carry all-identity rows — harmless)
            lblk = jnp.clip(plan.seg_blk - sg.w0 * plan.B_per_w, 0, nbl - 1)
            loc = scatter_op(op, loc, lblk, seg_out)
    inbox = loc.reshape((sg.m_loc, plan.B_per_w * plan.nb) + feat
                        )[:, :sg.n_loc]

    stats = None
    if count_cross:
        # mask-driven accounting (TracedPlan duck-types EdgePlan here)
        sh = planlib.plan_seg_hits(plan, flat_hits)
        seg_log = sg.log_of(plan.seg_worker)
        owner = plan.seg_blk // plan.B_per_w
        cross = sh & (owner != seg_log)[:, None]
        msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
        per_worker = jnp.zeros((sg.M,), jnp.int32).at[seg_log].add(
            cross.sum(axis=1).astype(jnp.int32))
        stats = (msgs, jax.lax.psum(per_worker, sg.axis))
    return inbox, stats


def _combine_sorted_rows_sharded(sg: ShardedGraph, targets, values, mask,
                                 op: str):
    """Sharded twin of plan.combine_sorted: the shared segment core
    (``plan.sorted_segments``) runs on the local (m_loc, K) rows, then the
    surviving segments are destination-routed (all_to_all rounds) into the
    owners' local buffers; source rows are rebased by ``w0`` for the
    accounting.  Crossness is mask-driven: a live segment IS >= 1 real
    message, whatever its combined payload."""
    n_pad = sg.n_pad
    real, seg_t, seg_val, seg_row, ident = planlib.sorted_segments(
        targets, values, mask, op, n_pad)

    buf = _routed_scatter_combine(sg, seg_t, seg_val, real, op)
    inbox = buf.reshape((sg.m_loc, sg.n_loc)
                        + planlib.feat_shape(values, 2))

    cross = real & (seg_t // sg.n_loc != seg_row + sg.w0)
    msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
    per_worker = _scatter_workers(sg, seg_row + sg.w0, cross)
    return inbox, (msgs, per_worker)


def _combine_sorted_flat_sharded(sg: ShardedGraph, targets, values, mask,
                                 worker, op: str,
                                 cap: Optional[int] = None):
    """Flat-csr twin: ``plan.sorted_segments_flat`` on the local (E_dev,)
    edges (source workers already global — physical shard ids under a
    split partition), destination-routed exchange, mask-driven counts."""
    n_pad = sg.n_pad
    real, seg_t, seg_val, seg_w, ident = planlib.sorted_segments_flat(
        targets, values, mask, worker, op, n_pad)

    buf = _routed_scatter_combine(sg, seg_t, seg_val, real, op, cap=cap)
    inbox = buf.reshape((sg.m_loc, sg.n_loc)
                        + planlib.feat_shape(values, 1))

    seg_log = sg.log_of(jnp.where(real, seg_w, 0))
    cross = real & (seg_t // sg.n_loc != seg_log)
    msgs = jax.lax.psum(cross.sum().astype(jnp.int32), sg.axis)
    per_worker = _scatter_workers(sg, seg_log, cross)
    return inbox, (msgs, per_worker)


def push_combined_sharded(sg: ShardedGraph, targets, values, mask, op: str,
                          backend: str = "dense",
                          plan: Optional[TracedPlan] = None):
    """Sharded Ch_msg, padded rows: local (m_loc, K) edges.  With a plan
    the combine runs destination-blocked through the kernel path; without
    one (dense backend, runtime targets) through the sorted segmented
    core.  Both exchange destination-routed — inboxes and stats are
    identical to the reference paths (min/max bitwise, stats exact)."""
    gw = sg.worker_ids()[:, None]
    raw_cross = mask & ((targets // sg.n_loc) != gw)
    base = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
            "per_worker_basic": _place_rows(sg, raw_cross.sum(axis=1))}

    if backend == "pallas" and plan is not None:
        ident = identity_of(op, values.dtype)
        masked = jnp.where(planlib.feat_mask(mask, values, 2), values,
                           ident)
        inbox, (msgs, pw) = _combine_with_plan_sharded(
            sg, plan, masked.reshape((-1,) + planlib.feat_shape(values, 2)),
            op, flat_hits=mask.reshape(-1))
    else:
        inbox, (msgs, pw) = _combine_sorted_rows_sharded(
            sg, targets, values, mask, op)
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(base)
    return inbox, stats


def push_combined_flat_sharded(sg: ShardedGraph, targets, values, mask,
                               worker, op: str, backend: str = "dense",
                               plan: Optional[TracedPlan] = None):
    """Sharded Ch_msg, csr layout: local flat (E_dev,) edges with global
    per-edge source workers (physical shard ids under a split partition —
    a shard never straddles devices, so the per-device distinct-pair
    accounting composes exactly across any device count)."""
    wlog = sg.log_of(worker)
    raw_cross = mask & ((targets // sg.n_loc) != wlog)
    base = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
            "per_worker_basic": _scatter_workers(sg, wlog, raw_cross)}

    if backend == "pallas" and plan is not None:
        ident = identity_of(op, values.dtype)
        masked = jnp.where(planlib.feat_mask(mask, values, 1), values,
                           ident)
        inbox, (msgs, pw) = _combine_with_plan_sharded(
            sg, plan, masked, op, flat_hits=mask)
    else:
        inbox, (msgs, pw) = _combine_sorted_flat_sharded(
            sg, targets, values, mask, worker, op,
            cap=(_cap_for(targets.shape[0], sg.D, sg.cap_hint)
                 if sg.cap_hint else None))
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(base)
    return inbox, stats


def push_mirror_sharded(sg: ShardedGraph, vals, active, op: str,
                        relay: str = "none", backend: str = "dense"):
    """Sharded Ch_mir: each device fetches the mirror values it actually
    references through the static mirror fetch plan (owner devices serve
    their active mirrored vertices; ONE statically-capped all_to_all —
    never an all-reduce over the full mirror set), then fans out on the
    local mirror edges.  Stats are owner-side and psum-merged: a mirrored
    vertex is owned by exactly one device, so the counts compose
    exactly."""
    ident = identity_of(op, vals.dtype)
    n_pad = sg.n_pad
    loc_n = sg.m_loc * sg.n_loc
    feat = planlib.feat_shape(vals, 2)
    flat_vals = vals.reshape((-1,) + feat)
    flat_act = active.reshape(-1)
    contrib = jnp.where(planlib.feat_mask(flat_act, flat_vals, 1),
                        flat_vals, ident)               # owner-side payload
    lv = _fetch_planned(sg, sg.fetch["mir"], contrib, ident)

    cesrc = (sg.mir_cesrc if sg.layout == "csr"
             else sg.mir_cesrc.reshape(sg.mir_esrc.shape))
    raw = lv[cesrc]
    ev = relay_values(raw, sg.mir_ew, relay, cesrc.ndim)
    if feat:
        # feature payloads can legitimately equal the identity, so edge
        # activity is fetched explicitly instead of read off the values
        la = _fetch_planned(sg, sg.fetch["mir"],
                            flat_act.astype(jnp.int32),
                            jnp.zeros((), jnp.int32))
        act_e = sg.mir_emask & (la[cesrc] > 0)
        ev = jnp.where(act_e[..., None], ev, ident)
    else:
        act_e = sg.mir_emask & (raw != ident)
        ev = jnp.where(act_e, ev, ident)
    if backend == "pallas":
        # a non-split partition's mirror edges are destination-sharded:
        # every plan segment is local, so the exchange is skipped
        inbox, _ = _combine_with_plan_sharded(
            sg, sg.plans["mir"], ev.reshape((-1,) + feat), op,
            count_cross=False, exchange=sg.split)
    elif sg.layout == "csr":
        if sg.split:
            # shard placement can put fan-out edges on a device that does
            # not own their destination rows: route the combined values
            buf = _routed_scatter_combine(sg, sg.mir_edst, ev, act_e, op)
            inbox = buf.reshape((sg.m_loc, sg.n_loc) + feat)
        else:
            buf = jnp.full((loc_n,) + feat, ident, vals.dtype)
            inbox = scatter_op(op, buf, sg.mir_edst - sg.w0 * sg.n_loc,
                               ev).reshape((sg.m_loc, sg.n_loc) + feat)
    else:
        def fan_out(edst, emask, ev_row):
            buf = jnp.full((sg.n_loc,) + feat, ident, vals.dtype)
            return scatter_op(op, buf, jnp.where(emask, edst, 0), ev_row)

        inbox = jax.vmap(fan_out)(sg.mir_edst, sg.mir_emask, ev)

    # owner-side mask-driven stats: an ACTIVE mirrored vertex is broadcast
    # to its hosting workers whatever its value; each device charges the
    # mirrored vertices it owns and the psum restores the exact totals
    safe_g = jnp.clip(sg.mir_ids, 0, n_pad - 1)
    valid = sg.mir_ids < n_pad
    slot = safe_g - sg.w0 * sg.n_loc
    owned = (slot >= 0) & (slot < loc_n)
    act = flat_act[jnp.clip(slot, 0, loc_n - 1)]
    sent = jnp.where(valid & owned & act, sg.mir_nworkers, 0)
    msgs = jax.lax.psum(sent.sum(), sg.axis)
    owner_w = jnp.clip(safe_g // sg.n_loc, 0, sg.M - 1)
    per_worker = jnp.zeros((sg.M,), sent.dtype).at[owner_w].add(sent)
    per_worker = jax.lax.psum(per_worker, sg.axis)
    return inbox, {"msgs_mirror": msgs, "per_worker_mirror": per_worker}


def broadcast_sharded(sg: ShardedGraph, vals, active, op: str,
                      relay: str = "none", use_mirroring: bool = True,
                      backend: str = "dense"):
    """Sharded twin of channels.broadcast (identical stats keys/values)."""
    esrc = sg.eg_src if use_mirroring else sg.all_src
    edst = sg.eg_dst if use_mirroring else sg.all_dst
    emask = sg.eg_mask if use_mirroring else sg.all_mask
    ew = sg.eg_w if use_mirroring else sg.all_w
    plan = (sg.plans.get("eg" if use_mirroring else "all")
            if backend == "pallas" else None)
    feat = planlib.feat_shape(vals, 2)
    if sg.layout == "csr":
        if sg.split:
            # edge-balanced device bounds: sources can be remote workers —
            # read them through the static source fetch plan (owner
            # devices serve exactly the slots this device's edges need)
            kind = "eg" if use_mirroring else "all"
            fp = sg.fetch[kind]
            csrc = sg.eg_csrc if use_mirroring else sg.all_csrc
            cv = _fetch_planned(sg, fp, vals.reshape((-1,) + feat),
                                jnp.zeros((), vals.dtype))
            ca = _fetch_planned(sg, fp,
                                active.reshape(-1).astype(jnp.int32),
                                jnp.zeros((), jnp.int32))
            src_val, src_act = cv[csrc], ca[csrc] > 0
            worker = sg.eg_pw if use_mirroring else sg.all_pw
        else:
            loc_src = esrc - sg.w0 * sg.n_loc
            src_val = vals.reshape((-1,) + feat)[loc_src]
            src_act = active.reshape(-1)[loc_src]
            worker = esrc // sg.n_loc
        v = relay_values(src_val, ew, relay, 1)
        inbox, stats = push_combined_flat_sharded(
            sg, edst, v, emask & src_act, worker, op,
            backend=backend, plan=plan)
    else:
        src_val = vals[jnp.arange(sg.m_loc)[:, None], esrc]
        src_act = active[jnp.arange(sg.m_loc)[:, None], esrc]
        v = relay_values(src_val, ew, relay, 2)
        inbox, stats = push_combined_sharded(sg, edst, v, emask & src_act,
                                             op, backend=backend, plan=plan)
    if use_mirroring:
        inbox2, s2 = push_mirror_sharded(sg, vals, active, op, relay,
                                         backend=backend)
        inbox = _MERGE[op](inbox, inbox2)
        stats.update(s2)
    else:
        stats["msgs_mirror"] = jnp.zeros((), jnp.int32)
        stats["per_worker_mirror"] = jnp.zeros((sg.M,), jnp.int32)
    stats["msgs_total"] = stats["msgs_combined"] + stats["msgs_mirror"]
    stats["per_worker_total"] = (stats["per_worker_combined"]
                                 + stats["per_worker_mirror"])
    return inbox, stats


def gather_sharded(sg: ShardedGraph, vals, targets, tmask,
                   dedup: bool = True):
    """Sharded Ch_req for row-shaped targets (m_loc, R): a real two-round
    trip — each worker's deduplicated requests route to the owner devices,
    owners answer from their local (m_loc, n_loc) shard, responses route
    back (``_routed_fetch``).  The request-respond *counts* (Theorem 3)
    are computed per device and psum-merged so they match the reference
    accounting exactly."""
    n_pad = sg.n_pad
    t = jnp.where(tmask, targets, n_pad)
    R = t.shape[1]
    if dedup:
        uniq, inv = jax.vmap(lambda r: _dedup_row(r, n_pad))(t)
    else:
        uniq = t
        inv = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32), t.shape)
    feat = planlib.feat_shape(vals, 2)
    flat_u = uniq.reshape(-1)
    got = _routed_fetch(sg, vals, flat_u, flat_u < n_pad
                        ).reshape(uniq.shape + feat)
    out = jnp.take_along_axis(got, planlib.feat_mask(inv, got, 2), axis=1)
    out = jnp.where(planlib.feat_mask(tmask, out, 2), out,
                    jnp.zeros((), vals.dtype))

    owner = jnp.clip(uniq // sg.n_loc, 0, sg.M - 1)
    uvalid = uniq < n_pad
    self_w = sg.worker_ids()[:, None]
    remote_u = uvalid & (owner != self_w)
    raw_remote = tmask & ((targets // sg.n_loc) != self_w)
    raw_owner = jnp.clip(targets // sg.n_loc, 0, sg.M - 1)
    stats = {
        "msgs_rr": 2 * jax.lax.psum(remote_u.sum(), sg.axis),
        "msgs_basic": 2 * jax.lax.psum(raw_remote.sum(), sg.axis),
        "per_worker_rr": (_place_rows(sg, remote_u.sum(1))
                          + _scatter_workers(sg, owner, remote_u)),
        "per_worker_basic": (_place_rows(sg, raw_remote.sum(1))
                             + _scatter_workers(sg, raw_owner, raw_remote)),
    }
    return out, stats


def gather_edges_sharded(sg: ShardedGraph, vals, targets, tmask,
                         dedup: bool = True):
    """Sharded Ch_req for edge-shaped targets (layout-dispatching).  The
    transport always rides the deduplicated (worker, target) segment heads
    — responses are propagated back down each segment — so the wire cost
    follows Theorem 3 regardless of the accounting mode requested."""
    if sg.layout != "csr":
        return gather_sharded(sg, vals, targets, tmask, dedup)
    n_pad = sg.n_pad
    worker = sg.all_pw if sg.split else sg.all_src // sg.n_loc
    wlog = sg.log_of(worker)
    t = jnp.where(tmask, targets, n_pad)
    L = t.shape[0]

    order, ws, ts, first = planlib.sort_by_worker_target(worker, t)
    heads = first & (ts < n_pad)
    cap = _cap_for(L, sg.D, sg.cap_hint) if sg.cap_hint else None
    head_vals = _routed_fetch(sg, vals, ts, heads, cap=cap)
    hidx = jax.lax.cummax(jnp.where(first, jnp.arange(L, dtype=jnp.int32),
                                    0))
    val_sorted = head_vals[hidx]
    feat = planlib.feat_shape(vals, 2)
    out = jnp.zeros((L,) + feat, vals.dtype).at[order].set(val_sorted)
    out = jnp.where(planlib.feat_mask(t < n_pad, out, 1), out,
                    jnp.zeros((), vals.dtype))

    owner = jnp.clip(targets // sg.n_loc, 0, sg.M - 1)
    raw_remote = tmask & ((targets // sg.n_loc) != wlog)
    if dedup:
        ws_log = sg.log_of(ws)
        uniq = heads
        remote_u = uniq & (ts // sg.n_loc != ws_log)
        u_w, u_owner = ws_log, jnp.clip(ts // sg.n_loc, 0, sg.M - 1)
    else:
        remote_u = raw_remote
        u_w, u_owner = wlog, owner
    stats = {
        "msgs_rr": 2 * jax.lax.psum(remote_u.sum(), sg.axis),
        "msgs_basic": 2 * jax.lax.psum(raw_remote.sum(), sg.axis),
        "per_worker_rr": (_scatter_workers(sg, u_w, remote_u)
                          + _scatter_workers(sg, u_owner, remote_u)),
        "per_worker_basic": (_scatter_workers(sg, wlog, raw_remote)
                             + _scatter_workers(sg, owner, raw_remote)),
    }
    return out, stats


def scatter_state_sharded(sg: ShardedGraph, base, targets, upd, mask,
                          op: str, backend: str = "dense"):
    """Sharded scatter-op for row-shaped runtime targets (S-V hooking).
    Runtime destinations admit no precomputed plan, so both backends share
    the sorted segmented combine + destination-routed exchange (the
    reference paths' stats are identical by construction, and min/max
    values are order-exact)."""
    gw = sg.worker_ids()[:, None]
    raw_cross = mask & ((targets // sg.n_loc) != gw)
    bstats = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
              "per_worker_basic": _place_rows(sg, raw_cross.sum(axis=1))}
    inbox, (msgs, pw) = _combine_sorted_rows_sharded(sg, targets, upd,
                                                     mask, op)
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(bstats)
    return _MERGE[op](base, inbox), stats


def scatter_edges_sharded(sg: ShardedGraph, base, targets, upd, mask,
                          op: str, backend: str = "dense"):
    """Sharded scatter-op for edge-shaped runtime targets (MSF election)."""
    if sg.layout != "csr":
        return scatter_state_sharded(sg, base, targets, upd, mask, op,
                                     backend)
    worker = sg.all_pw if sg.split else sg.all_src // sg.n_loc
    wlog = sg.log_of(worker)
    raw_cross = mask & ((targets // sg.n_loc) != wlog)
    bstats = {"msgs_basic": jax.lax.psum(raw_cross.sum(), sg.axis),
              "per_worker_basic": _scatter_workers(sg, wlog, raw_cross)}
    inbox, (msgs, pw) = _combine_sorted_flat_sharded(sg, targets, upd,
                                                     mask, worker, op)
    stats = {"msgs_combined": msgs, "per_worker_combined": pw}
    stats.update(bstats)
    return _MERGE[op](base, inbox), stats


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def _state_specs(tree, M: int, hier=None):
    row = P((HAXIS, AXIS)) if hier else P(AXIS)
    return jax.tree.map(
        lambda x: row if (getattr(x, "ndim", 0) >= 1
                          and x.shape[0] == M) else P(), tree)


def _acc_specs(stats_shape):
    """PartitionSpec pytree matching bsp's (hi, lo) limb accumulator."""
    return [
        (P(), P()) if jnp.issubdtype(leaf.dtype, jnp.integer) else P()
        for leaf in jax.tree.leaves(stats_shape)
    ]


def build_sharded(pg, make_step: Callable, state0, max_supersteps: int,
                  record_history: bool = False, devices: int = 1,
                  plan_kinds: Sequence[str] = (), pipeline: bool = False,
                  pipeline_chunks: Optional[int] = None,
                  profile: Optional[ShardProfile] = None,
                  on_trace: Optional[Callable] = None):
    """Build the jitted sharded BSP program.  Returns (fn, args) with
    ``fn(*args) == (final_state, raw_acc, n_supersteps, history)`` —
    fold ``raw_acc`` with ``finalize_stats`` (run_sharded does) to get
    the ``bsp.run`` totals contract.

    ``make_step(g)`` must build the superstep function against either a
    PartitionedGraph (used here only to trace the stats structure) or the
    device-local ShardedGraph.

    ``pipeline=True`` turns on the double-buffered superstep: every
    routed exchange is chunked (~``pipeline_chunks`` chunks, default
    ``DEFAULT_PIPELINE_CHUNKS`` on a multi-device mesh, 1 on a single
    device where the all_to_all is a local transpose and chunk overhead
    buys nothing) so chunk k's all_to_all overlaps chunk k-1's local
    combine, and the (hi, lo) stats fold is deferred one superstep
    (``bsp.run(pipeline=True)``).  Results keep the parity contract:
    min/max/int bitwise, stats integer-exact, float sums within the
    usual exchange-order tolerance.

    ``devices`` may also be an ``(hosts, per_host)`` pair: the program
    then runs on the 2-D mesh with the hierarchical two-leg exchanges
    (combine within the host, route the residue across hosts), same
    parity contract against the 1-D path.

    ``profile`` pads the shard arrays to a frozen :class:`ShardProfile`
    envelope so a resident program survives graph folds with ZERO
    re-traces (feed ``reshard_arrays`` outputs to the returned fn);
    ``on_trace`` is called (Python side effect) each time the inner
    program actually traces — the serving trace counter."""
    D, hier = _normalize_devices(devices)
    if pg.M % D:
        raise ValueError(f"M={pg.M} workers must divide over "
                         f"devices={devices}")
    mesh = graph_mesh(devices)
    meta, arrays, arr_specs = _shard_graph(pg, devices, plan_kinds,
                                           pipeline, pipeline_chunks)
    if profile is not None:
        _apply_profile(meta, arrays, profile)

    _, _, stats_shape = jax.eval_shape(make_step(pg), state0,
                                       jnp.zeros((), jnp.int32))
    st_specs = _state_specs(state0, pg.M, hier)
    stats_specs = jax.tree.map(lambda _: P(), stats_shape)
    hist_specs = stats_specs if record_history else None

    def inner(arrs, st0):
        if on_trace is not None:
            on_trace()
        sg = _make_sg(meta, arrs)
        return bsp.run(make_step(sg), st0, max_supersteps, record_history,
                       raw_totals=True, pipeline=pipeline)

    fn = shard_map(inner, mesh=mesh,
                   in_specs=(arr_specs, st_specs),
                   out_specs=(st_specs, _acc_specs(stats_shape), P(),
                              hist_specs),
                   check_rep=False)
    return jax.jit(fn), (arrays, state0), stats_shape


def finalize_stats(raw_acc, stats_shape):
    """Fold the limb accumulator returned by a ``build_sharded`` program
    into exact host-side totals (Python ints / numpy int64)."""
    _, treedef = jax.tree.flatten(stats_shape)
    return bsp.finalize_totals(raw_acc, treedef)


def run_sharded(pg, make_step: Callable, state0, max_supersteps: int,
                record_history: bool = False, devices: int = 1,
                plan_kinds: Sequence[str] = (), pipeline: bool = False,
                pipeline_chunks: Optional[int] = None):
    """Run a BSP program sharded over ``devices`` devices; same return
    contract as ``bsp.run`` (stats totals folded into exact host int64)."""
    fn, args, stats_shape = build_sharded(pg, make_step, state0,
                                          max_supersteps, record_history,
                                          devices, plan_kinds, pipeline,
                                          pipeline_chunks)
    st, raw_acc, n, hist = fn(*args)
    return st, finalize_stats(raw_acc, stats_shape), n, hist


def build_apply(pg, make_fn: Callable, args: Tuple, devices: int = 1,
                plan_kinds: Sequence[str] = (), pipeline: bool = False,
                pipeline_chunks: Optional[int] = None,
                out_rule: str = "rows",
                is_sharded: Optional[Callable] = None):
    """Build (but don't run) a one-shot sharded channel application:
    returns ``(fn, arrays)`` with ``fn(arrays, args) == make_fn(sg)(*args)``
    jitted once — callers that re-apply the same join with fresh ``args``
    (a training loop stepping the same graph) pay ONE compilation instead
    of one per call.  Input leaves with leading axis ``pg.M`` are
    worker-sharded, the rest replicated.  ``out_rule`` picks the output
    placement: ``"rows"`` (the historical contract) marks every ``out``
    leaf worker-sharded; ``"auto"`` keys each ``out`` leaf by the same
    leading-axis test as the inputs — what a mixed pytree of sharded
    row-state and replicated dense parameters (a training step) needs.
    ``is_sharded`` replaces the leading-axis test with a caller predicate
    (leaf -> bool) for pytrees where a replicated leaf's first dim could
    coincide with ``pg.M`` (e.g. a (M, hidden) weight matrix)."""
    D, hier = _normalize_devices(devices)
    if pg.M % D:
        raise ValueError(f"M={pg.M} workers must divide over "
                         f"devices={devices}")
    mesh = graph_mesh(devices)
    meta, arrays, arr_specs = _shard_graph(pg, devices, plan_kinds,
                                           pipeline, pipeline_chunks)
    row_spec = P((HAXIS, AXIS)) if hier else P(AXIS)

    def _spec_of(x):
        if is_sharded is not None:
            return row_spec if is_sharded(x) else P()
        return row_spec if (getattr(x, "ndim", 0) >= 1
                            and x.shape[0] == pg.M) else P()

    in_specs = jax.tree.map(_spec_of, args)
    out_shape, stats_shape = jax.eval_shape(make_fn(pg), *args)
    out_leaf = (_spec_of if out_rule == "auto"
                else (lambda _: row_spec))
    out_specs = (jax.tree.map(out_leaf, out_shape),
                 jax.tree.map(lambda _: P(), stats_shape))

    def inner(arrs, a):
        sg = _make_sg(meta, arrs)
        return make_fn(sg)(*a)

    fn = shard_map(inner, mesh=mesh, in_specs=(arr_specs, in_specs),
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn), arrays


def apply_sharded(pg, make_fn: Callable, args: Tuple, devices: int = 1,
                  plan_kinds: Sequence[str] = (), pipeline: bool = False,
                  pipeline_chunks: Optional[int] = None):
    """One-shot sharded channel application (no BSP loop): ``make_fn(sg)``
    returns ``fn(*local_args) -> (out, stats)`` where every ``out`` leaf is
    worker/edge-sharded on its leading axis and ``stats`` is replicated.
    csr edge-shaped outputs come back device-concatenated with per-device
    padding — strip with ``csr_device_bounds``."""
    fn, arrays = build_apply(pg, make_fn, args, devices, plan_kinds,
                             pipeline, pipeline_chunks)
    return fn(arrays, args)


def exchange_volume_report(pg, devices, plan_kinds: Sequence[str] = ()):
    """Static per-superstep exchange-volume accounting from the shard
    tables (host-side; no compilation).  Counts the wire lanes of every
    static exchange the executor runs per superstep — the plan exchanges
    (Ch_msg/Ch_mir on the pallas backend) and the fetch plans (mirror
    values, split source reads):

    * 1-D mesh: every lane between two distinct devices is ``intra_host``
      (one host) and ``cross_host`` is 0 — ``total`` is the flat
      all-pairs volume the hierarchical gate compares against.
    * 2-D mesh: leg-1 lanes leaving their column (intra-host wire) count
      as ``intra_host``; leg-2 / leg-A lanes leaving their host count as
      ``cross_host``.  The intermediate combine means ``cross_host`` is
      the *post-combine residue* — the per-level Theorem-1 bound in
      action, and the number the bench gate requires to be strictly
      below the flat all-pairs volume."""
    D, hier = _normalize_devices(devices)
    meta, arrays, _ = _shard_graph(pg, devices, plan_kinds)
    dev = np.arange(D)
    rep = {"devices": D, "hier": hier, "per_exchange": {}}
    intra = cross = 0

    def add(name, i, c):
        rep["per_exchange"][name] = {"intra_host": int(i),
                                     "cross_host": int(c)}

    for kind in meta["plan_meta"]:
        if hier:
            H, T = hier
            snd1 = np.asarray(arrays[f"plan_{kind}_x1val"]).sum(axis=2)
            i_k = int(snd1[(dev % T)[:, None] != np.arange(T)[None]].sum())
            snd2 = np.asarray(arrays[f"plan_{kind}_x2val"]).sum(axis=2)
            c_k = int(snd2[(dev // T)[:, None] != np.arange(H)[None]].sum())
        else:
            snd = np.asarray(arrays[f"plan_{kind}_xval"]).sum(axis=2)
            i_k, c_k = int(snd.sum() - np.trace(snd)), 0
        add(f"plan_{kind}", i_k, c_k)
        intra, cross = intra + i_k, cross + c_k
    for name in meta["fetch_meta"]:
        if hier:
            H, T = hier
            a_snd = (np.asarray(arrays[f"fetch_{name}_a_send"]) >= 0
                     ).sum(axis=2)
            c_k = int(a_snd[(dev // T)[:, None] != np.arange(H)[None]].sum())
            b_snd = (np.asarray(arrays[f"fetch_{name}_b_send"]) >= 0
                     ).sum(axis=2)
            i_k = int(b_snd[(dev % T)[:, None] != np.arange(T)[None]].sum())
        else:
            snd = (np.asarray(arrays[f"fetch_{name}_send_slot"]) >= 0
                   ).sum(axis=2)
            i_k, c_k = int(snd.sum() - np.trace(snd)), 0
        add(f"fetch_{name}", i_k, c_k)
        intra, cross = intra + i_k, cross + c_k
    rep.update(intra_host=intra, cross_host=cross, total=intra + cross)
    return rep

"""Persistent graph service: a resident sharded graph, streaming
mutations, and batched concurrent point queries.

Everything else in the repo is batch — partition once, run one
algorithm, exit.  This module keeps the partitioned, sharded graph LIVE
on the mesh and serves traffic from it:

* **Resident executors, zero re-traces.**  Every query program is built
  ONCE per batch bucket against a frozen :class:`~repro.core.exec.
  ShardProfile`; after warmup, admission never re-traces (the service
  counts traces — the serve_graph demo asserts the counter stays flat
  across batches AND across mutations).

* **Streaming mutations with an epoch barrier.**  ``mutate()`` enqueues
  an :class:`~repro.graph.structs.EdgeDelta`; the next ``pump()`` folds
  every pending delta into the flat csr layout (``fold_delta`` — no
  re-partition, perm pinned), bumps the graph epoch, and re-pads the
  shard arrays to the frozen profile.  Queries are only served BETWEEN
  folds, so every in-flight query reads exactly one epoch's snapshot —
  never a mix.

* **Query batching, coalescing, and an epoch-keyed result cache.**
  Queries are admitted a batch at a time; duplicate (kind, source)
  pairs in a batch collapse to one executor lane; results are cached
  per (epoch, kind, source) so repeats are free until the next
  mutation invalidates them (by key, not by flushing).

* **One compiled executor per bucket, three query kinds.**  Landmark /
  batched SSSP and personalized PageRank share a single unified BSP
  step: per-query source columns ride the trailing feature axis as
  ``(lanes, Q)`` blocks (the PR-8 vector-payload path), so a 64-query
  batch costs one BSP run, not 64.  Batch sizes are padded up to fixed
  buckets (default 4/16/64) with dummy lanes so the executor cache is
  tiny and admission never compiles.  Ego-component lookups are served
  from per-epoch Hash-Min labels computed lazily ONCE per epoch on a
  resident profile-stable program.

The client protocol is the ``Query`` / ``QueryResult`` dataclass pair;
:class:`GraphClient` speaks it over a direct method call (a socket
transport would carry the same messages — the service loop is already
single-writer round-based, exactly like ``launch/serve_model.py``'s
request loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.api import Engine, EngineConfig
from repro.core import exec as exec_mod
from repro.core.channels import broadcast
from repro.core.plan import identity_of
from repro.graph import structs

KINDS = ("sssp", "ppr", "ego")


@dataclasses.dataclass(frozen=True)
class Query:
    """A point query against the resident graph.  ``source`` is an
    ORIGINAL vertex id; ``kind`` one of ``sssp`` (distances from
    source), ``ppr`` (personalized PageRank mass seeded at source) or
    ``ego`` (the source's component root + size)."""
    kind: str
    source: int


@dataclasses.dataclass
class QueryResult:
    """``value``: (n,) float32 per-original-vertex distances (sssp) or
    ppr mass, or an ``(root, size)`` pair (ego).  ``epoch`` names the
    graph snapshot the answer was computed on; ``cached`` marks an
    epoch-keyed cache hit (no executor lanes spent)."""
    query: Query
    epoch: int
    value: Any
    cached: bool = False


class GraphService:
    """Resident graph + admission queue + bucketed batch executors.

    Single-writer, round-based: ``pump()`` alternates [fold pending
    mutations -> bump epoch] with [serve one admitted batch], which IS
    the mutation epoch barrier — a batch can never straddle a fold.
    """

    def __init__(self, graph: structs.Graph, M: int = 32,
                 tau: Optional[int] = None,
                 config: Optional[EngineConfig] = None,
                 buckets: Sequence[int] = (4, 16, 64),
                 ppr_alpha: float = 0.15, ppr_iters: int = 20,
                 max_supersteps: int = 512,
                 profile_slack: float = 1.5, seed: int = 0,
                 rebalance_threshold: Optional[float] = None):
        if config is None:
            config = EngineConfig(layout="csr", balance="edges", devices=1)
        if config.layout != "csr" or config.balance == "split":
            raise ValueError("the resident service needs layout='csr' "
                             "and a non-split balance mode ('hash', "
                             "'edges', 'edges+refine', 'vertex-cut') — "
                             "the ShardProfile restrictions")
        if config.backend != "dense":
            raise ValueError("the resident service runs backend='dense' "
                             "(plan tables are content-shaped and would "
                             "re-trace on every fold)")
        self.engine = Engine(config)
        self.devices = config.devices if config.devices is not None else 1
        self.g = graph
        self.M, self.tau, self.seed = int(M), tau, int(seed)
        self.rebalance_threshold = rebalance_threshold
        self.repartitions = 0
        self.pg = self.engine.partition(graph, M, tau=tau, seed=seed)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.ppr_alpha = float(ppr_alpha)
        self.ppr_iters = int(ppr_iters)
        self.max_supersteps = int(max_supersteps)
        self.profile_slack = float(profile_slack)
        self.profile = exec_mod.shard_profile(self.pg, self.devices,
                                              slack=profile_slack)
        self.arrays = exec_mod.reshard_arrays(self.pg, self.devices,
                                              self.profile)
        self.epoch = 0
        self.traces = 0          # Python-side count of executor traces
        self.last_batch: Dict[str, Any] = {}
        self.last_pump: Dict[str, Any] = {}
        self._execs: Dict[int, Tuple] = {}   # bucket -> (fn, stats_shape)
        self._cc: Optional[Tuple] = None     # resident Hash-Min program
        self._labels: Optional[Tuple] = None  # (epoch, root, size) arrays
        self._queue: List[Tuple[int, Query]] = []
        self._results: Dict[int, QueryResult] = {}
        self._cache: Dict[Tuple, Any] = {}
        self._pending: List[structs.EdgeDelta] = []
        self._next_ticket = 0
        # every query needs a real relabeled slot for its dummy lanes
        self._dummy_src = int(self.pg.perm[0])

    # -- client-facing surface -------------------------------------------

    def submit(self, queries: Sequence[Query]) -> List[int]:
        """Enqueue queries; returns their tickets (serve with pump())."""
        tickets = []
        for q in queries:
            if q.kind not in KINDS:
                raise ValueError(f"unknown query kind {q.kind!r}")
            if not (0 <= q.source < self.pg.n):
                raise ValueError(f"source {q.source} outside the vertex "
                                 f"universe [0, {self.pg.n})")
            t = self._next_ticket
            self._next_ticket += 1
            self._queue.append((t, q))
            tickets.append(t)
        return tickets

    def mutate(self, delta: structs.EdgeDelta) -> None:
        """Enqueue a streaming edge delta; folded at the next pump()
        BEFORE any queued query is served (the epoch barrier)."""
        self._pending.append(delta)

    def take_result(self, ticket: int) -> QueryResult:
        return self._results.pop(ticket)

    def pump(self) -> int:
        """One service round: fold pending mutations, then serve every
        admitted query (in bucket-bounded slices).  Returns the number
        of results produced."""
        self._fold_pending()
        served = 0
        self.last_pump = {"slices": 0, "lanes_sssp": 0, "lanes_ppr": 0,
                          "n_supersteps": 0, "epoch": self.epoch}
        while self._queue:
            maxb = self.buckets[-1]
            batch: List[Tuple[int, Query]] = []
            lanes = {"sssp": set(), "ppr": set()}
            while self._queue:
                t, q = self._queue[0]
                if q.kind in lanes:
                    lanes[q.kind].add(q.source)
                    if max(len(lanes["sssp"]), len(lanes["ppr"])) > maxb:
                        break
                batch.append(self._queue.pop(0))
            self._serve_batch(batch)
            served += len(batch)
        self._maybe_repartition()
        return served

    def warmup(self) -> None:
        """Build + trace every bucket executor and the component program
        with dummy lanes, so no later admission ever compiles."""
        for b in self.buckets:
            self._run_exec(b, [self._dummy_src], [self._dummy_src])
        self._labels_now()

    # -- mutation folding (the epoch barrier) ----------------------------

    def _fold_pending(self) -> None:
        if not self._pending:
            return
        for d in self._pending:
            self.pg = structs.fold_delta(self.pg, d)
            self.g = structs.apply_delta(self.g, d)
        self._pending = []
        self.epoch += 1
        self._labels = None
        # stale cache keys can never hit again; drop them to stay small
        self._cache = {k: v for k, v in self._cache.items()
                       if k[0] == self.epoch}
        try:
            self.arrays = exec_mod.reshard_arrays(self.pg, self.devices,
                                                  self.profile)
        except exec_mod.ProfileOverflow:
            # the graph outgrew its envelope: freeze a bigger one and
            # drop the resident programs (they re-warm lazily)
            self.profile = exec_mod.shard_profile(
                self.pg, self.devices, slack=self.profile_slack)
            self.arrays = exec_mod.reshard_arrays(self.pg, self.devices,
                                                  self.profile)
            self._execs.clear()
            self._cc = None

    # -- telemetry-driven elastic repartition ----------------------------

    def repartition(self) -> None:
        """Re-run the configured partitioner on the CURRENT graph and
        reshard under the frozen profile — a fresh assignment (folds
        only ever *grow* the monotone ``pair_counts`` caps; this
        re-tightens them to fresh-partition values) at reshard cost,
        never a re-trace storm: the compiled bucket executors take the
        shard arrays (vmask/deg included) as arguments, so only the
        resident Hash-Min program — whose cached ``state0`` bakes in
        the old perm — is rebuilt.  The epoch does NOT bump: the graph
        content is unchanged, so epoch-keyed cached answers stay
        valid."""
        self.pg = self.engine.partition(self.g, self.M, tau=self.tau,
                                        seed=self.seed)
        try:
            self.arrays = exec_mod.reshard_arrays(self.pg, self.devices,
                                                  self.profile)
        except exec_mod.ProfileOverflow:
            # the fresh assignment needs a bigger envelope: re-freeze
            # and drop the resident programs (they re-warm lazily)
            self.profile = exec_mod.shard_profile(
                self.pg, self.devices, slack=self.profile_slack)
            self.arrays = exec_mod.reshard_arrays(self.pg, self.devices,
                                                  self.profile)
            self._execs.clear()
            self._cc = None
        if self._cc is not None:
            # the compiled Hash-Min fn is profile-shaped and survives a
            # reshard; only its cached state0 bakes in the old perm
            fn, _, stats_shape = self._cc
            imax = identity_of("min", jnp.int32)
            ids = self.pg.local_ids().astype(jnp.int32)
            state0 = (jnp.where(self.pg.vmask, ids, imax),
                      self.pg.vmask)
            self._cc = (fn, state0, stats_shape)
        self._labels = None
        self._dummy_src = int(self.pg.perm[0])
        self.repartitions += 1

    def _maybe_repartition(self) -> None:
        """The pump()-level elastic trigger: when the measured
        per-worker message load of the last served batch drifts past
        ``rebalance_threshold`` (max/mean), the next partition is
        computed fresh."""
        if self.rebalance_threshold is None or not self.last_batch:
            return
        pw = np.asarray(self.last_batch["stats"].get(
            "per_worker_total", ()), np.float64)
        if pw.size == 0 or pw.mean() <= 0:
            return
        if float(pw.max() / pw.mean()) > float(self.rebalance_threshold):
            self.repartition()

    # -- the unified batched SSSP + PPR executor -------------------------

    def _bucket_for(self, k: int) -> int:
        for b in self.buckets:
            if k <= b:
                return b
        return self.buckets[-1]

    def _count_trace(self) -> None:
        self.traces += 1

    def _make_query_step(self):
        cfg = self.engine.config
        alpha, iters = self.ppr_alpha, self.ppr_iters

        def make_step(g):
            def step(state, i):
                dist, dact, pr, restart = state
                # landmark SSSP: Q distance columns ride the feature axis
                inbox_d, s1 = broadcast(g, dist, dact, op="min",
                                        relay="add_w",
                                        use_mirroring=cfg.use_mirroring,
                                        backend=cfg.backend)
                upd = g.vmask[..., None] & (inbox_d < dist)
                dist = jnp.where(upd, inbox_d, dist)
                dact = jnp.any(upd, axis=-1)
                # personalized PageRank: power iteration on the same
                # superstep, frozen after exactly ``iters`` iterations
                deg = jnp.maximum(g.deg, 1)[..., None]
                contrib = jnp.where(g.vmask[..., None], pr / deg, 0.0)
                pact = g.vmask & (g.deg > 0)
                inbox_p, s2 = broadcast(g, contrib, pact, op="sum",
                                        use_mirroring=cfg.use_mirroring,
                                        backend=cfg.backend)
                pr_new = jnp.where(g.vmask[..., None],
                                   alpha * restart
                                   + (1 - alpha) * inbox_p, 0.0)
                pr = jnp.where(i < iters, pr_new, pr)
                stats = {k: s1[k] + s2[k] for k in s1}
                halted = (~g.gany(upd)) & (i + 1 >= iters)
                return (dist, dact, pr, restart), halted, stats
            return step
        return make_step

    def _query_state(self, s_rel: np.ndarray, p_rel: np.ndarray):
        """Initial state for relabeled source slots (already padded to
        the bucket width)."""
        pg = self.pg
        n_pad, qs, qp = pg.n_pad, len(s_rel), len(p_rel)
        vm = np.asarray(pg.vmask).reshape(-1)
        dist0 = np.full((n_pad, qs), np.inf, np.float32)
        dist0[s_rel, np.arange(qs)] = 0.0
        dact0 = np.zeros(n_pad, bool)
        dact0[s_rel] = True
        restart = np.zeros((n_pad, qp), np.float32)
        restart[p_rel, np.arange(qp)] = 1.0
        shape = (pg.M, pg.n_loc)
        return (jnp.asarray(dist0.reshape(shape + (qs,))),
                jnp.asarray((dact0 & vm).reshape(shape)),
                jnp.asarray(restart.reshape(shape + (qp,))),
                jnp.asarray(restart.reshape(shape + (qp,))))

    def _run_exec(self, b: int, s_rel: List[int], p_rel: List[int]):
        """Run the bucket-``b`` executor on padded source lists; returns
        (dist (n_pad, b), ppr (n_pad, b), stats, n_supersteps)."""
        pad = lambda xs: np.asarray(   # noqa: E731
            list(xs) + [self._dummy_src] * (b - len(xs)), np.int64)
        state0 = self._query_state(pad(s_rel), pad(p_rel))
        if b not in self._execs:
            fn, _, stats_shape = exec_mod.build_sharded(
                self.pg, self._make_query_step(), state0,
                self.max_supersteps, devices=self.devices,
                profile=self.profile, on_trace=self._count_trace)
            self._execs[b] = (fn, stats_shape)
        fn, stats_shape = self._execs[b]
        st, acc, n, _ = fn(self.arrays, state0)
        dist = np.asarray(st[0]).reshape(self.pg.n_pad, b)
        pr = np.asarray(st[2]).reshape(self.pg.n_pad, b)
        stats = exec_mod.finalize_stats(acc, stats_shape)
        return dist, pr, stats, int(n)

    # -- per-epoch component labels (ego lookups) ------------------------

    def _labels_now(self):
        if self._labels is not None and self._labels[0] == self.epoch:
            return self._labels
        if self._cc is None:
            cfg = self.engine.config
            imax = identity_of("min", jnp.int32)

            def make_step(g):
                def step(state, i):
                    minv, active = state
                    inbox, stats = broadcast(
                        g, minv, active, op="min",
                        use_mirroring=cfg.use_mirroring,
                        backend=cfg.backend)
                    upd = g.vmask & (inbox < minv)
                    new = jnp.where(upd, inbox, minv)
                    return (new, upd), ~g.gany(upd), stats
                return step

            ids = self.pg.local_ids().astype(jnp.int32)
            state0 = (jnp.where(self.pg.vmask, ids, imax), self.pg.vmask)
            fn, _, stats_shape = exec_mod.build_sharded(
                self.pg, make_step, state0, self.max_supersteps,
                devices=self.devices, profile=self.profile,
                on_trace=self._count_trace)
            self._cc = (fn, state0, stats_shape)
        fn, state0, _ = self._cc
        st, _, _, _ = fn(self.arrays, state0)
        root = structs.canonical_labels(self.pg, st[0])  # (n,) min orig id
        _, inv, counts = np.unique(root, return_inverse=True,
                                   return_counts=True)
        self._labels = (self.epoch, root, counts[inv])
        return self._labels

    # -- batch serving ----------------------------------------------------

    def _serve_batch(self, batch: List[Tuple[int, Query]]) -> None:
        pre_cached = {(self.epoch, q.kind, q.source) for _, q in batch
                      if (self.epoch, q.kind, q.source) in self._cache}
        need: Dict[str, List[int]] = {"sssp": [], "ppr": []}
        for _, q in batch:
            key = (self.epoch, q.kind, q.source)
            if key in self._cache or q.kind == "ego":
                continue
            if q.source not in need[q.kind]:
                need[q.kind].append(q.source)
        n_lanes = max(len(need["sssp"]), len(need["ppr"]))
        if n_lanes:
            b = self._bucket_for(n_lanes)
            s_rel = [int(self.pg.perm[v]) for v in need["sssp"]]
            p_rel = [int(self.pg.perm[v]) for v in need["ppr"]]
            dist, pr, stats, n = self._run_exec(b, s_rel, p_rel)
            # per-query original-id-order vectors
            dists = dist[self.pg.perm]   # (n, b)
            prs = pr[self.pg.perm]
            for j, v in enumerate(need["sssp"]):
                self._cache[(self.epoch, "sssp", v)] = dists[:, j].copy()
            for j, v in enumerate(need["ppr"]):
                self._cache[(self.epoch, "ppr", v)] = prs[:, j].copy()
            self.last_batch = {"bucket": b, "epoch": self.epoch,
                               "lanes_sssp": len(s_rel),
                               "lanes_ppr": len(p_rel),
                               "n_supersteps": n, "stats": stats}
            lp = self.last_pump
            lp["slices"] += 1
            lp["lanes_sssp"] += len(s_rel)
            lp["lanes_ppr"] += len(p_rel)
            lp["n_supersteps"] += n
        if any(q.kind == "ego" for _, q in batch):
            _, root, size = self._labels_now()
            for _, q in batch:
                if q.kind == "ego":
                    self._cache[(self.epoch, "ego", q.source)] = (
                        int(root[q.source]), int(size[q.source]))
        for t, q in batch:
            key = (self.epoch, q.kind, q.source)
            self._results[t] = QueryResult(
                query=q, epoch=self.epoch, value=self._cache[key],
                cached=key in pre_cached)

    # convenience for tests / benchmarks
    def snapshot_graph(self) -> structs.Graph:
        """The host-side edge list of the CURRENT epoch (reference
        oracle input)."""
        return self.g


class GraphClient:
    """In-process client speaking the Query/QueryResult protocol.  The
    transport is a direct call into the service's admission queue — a
    remote transport would serialize the same dataclasses."""

    def __init__(self, service: GraphService):
        self.service = service

    def request(self, queries: Sequence[Query]) -> List[QueryResult]:
        """Submit a batch and drive the service until every answer is
        in; results come back in submission order."""
        tickets = self.service.submit(queries)
        while any(t not in self.service._results for t in tickets):
            self.service.pump()
        return [self.service.take_result(t) for t in tickets]

    def sssp(self, source: int) -> QueryResult:
        return self.request([Query("sssp", source)])[0]

    def ppr(self, source: int) -> QueryResult:
        return self.request([Query("ppr", source)])[0]

    def ego(self, source: int) -> QueryResult:
        return self.request([Query("ego", source)])[0]

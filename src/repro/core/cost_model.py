"""The paper's cost model: Theorems 1-3, the mirroring threshold, and the
load-balance model behind ``partition(..., balance=...)``.

Theorem 1: with mirroring, a vertex v delivers a(v) to all neighbors with
           <= min(M, d(v)) messages.
Theorem 2: mirror v iff d(v) >= tau* = M * exp(deg_avg / M)  (the point
           where mirroring beats sender-side combining in expectation).
Theorem 3: request-respond serves l requesters of one target with
           2*min(M, l) messages instead of 2*l.

Load balancing (paper §4 / GraphD): per-worker *edge* load, not vertex
count, governs superstep wall time.  ``vertex_cost`` prices each vertex as
local edge storage plus its per-superstep message bound (Theorem 1 for
mirrored vertices), ``greedy_assign`` packs vertices onto workers LPT-style
under the block-partition capacity, ``choose_split`` decides how many
physical shards a still-hot worker needs, and ``contiguous_bounds``
partitions a run of physical shards over devices minimizing the bottleneck.
``straggler_report`` quantifies the imbalance that remains (Figs. 1/2).

``moe_mirror_threshold`` transfers Theorem 2 to expert parallelism: an
expert whose per-step routed-token load exceeds the threshold is cheaper to
replicate (mirror) on every EP rank than to keep exchanging tokens.
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, Optional

import numpy as np


def mirror_threshold(M: int, deg_avg: float) -> float:
    """Theorem 2: tau* = M * exp(deg_avg / M)."""
    return M * math.exp(deg_avg / M)


def thm1_bound(M: int, degree: int) -> int:
    return min(M, degree)


def thm3_bound(M: int, n_requesters: int) -> int:
    return 2 * min(M, n_requesters)


def expected_messages_combined(deg: np.ndarray, M: int) -> float:
    """Expected #messages for one all-neighbors broadcast through the
    combined channel under the paper's random-graph model: each vertex's
    message to a neighbor survives combining with prob exp(-deg_avg/M)
    (proof of Thm 2)."""
    deg_avg = float(deg.mean())
    return float(deg.sum() * math.exp(-deg_avg / M))


def expected_messages_mirrored(deg: np.ndarray, M: int, tau: float) -> float:
    """Expected #messages when vertices with d >= tau are mirrored."""
    hi = deg >= tau
    deg_avg = float(deg.mean())
    lo_msgs = float(deg[~hi].sum() * math.exp(-deg_avg / M))
    hi_msgs = float(np.minimum(deg[hi], M).sum())
    return lo_msgs + hi_msgs


def choose_tau(deg: np.ndarray, M: int) -> int:
    """The cost model's automatic threshold (rounded)."""
    return int(round(mirror_threshold(M, float(deg.mean()))))


# ---------------------------------------------------------------------------
# load-balance model: vertex costs, greedy assignment, hot-worker splitting
# ---------------------------------------------------------------------------

def vertex_cost(deg: np.ndarray, M: int,
                tau: Optional[int] = None) -> np.ndarray:
    """Per-vertex balance cost for ``balance="edges"``: local edge storage
    (d(v) adjacency entries) plus the per-superstep message bound — the
    Theorem-1 bound min(M, d(v)) for mirrored vertices (d >= tau), d(v)
    itself for combined-channel vertices."""
    deg = np.asarray(deg, np.int64)
    tau_eff = int(tau) if tau is not None else int(deg.max(initial=0)) + 1
    msg = np.where(deg >= tau_eff, np.minimum(deg, M), deg)
    return deg + msg


def greedy_assign(cost: np.ndarray, M: int, cap: int) -> np.ndarray:
    """LPT vertex->worker assignment under the block-partition capacity:
    vertices in descending cost order each go to the least-loaded worker
    that still has a free local slot (at most ``cap`` vertices per worker).
    Returns the (n,) int64 worker id per vertex."""
    cost = np.asarray(cost, np.int64)
    n = len(cost)
    if M * cap < n:
        raise ValueError(f"capacity {M}x{cap} < {n} vertices")
    order = np.argsort(-cost, kind="stable")
    assign = np.empty(n, np.int64)
    remaining = np.full(M, cap, np.int64)
    heap = [(0, w) for w in range(M)]
    for v in order:
        load, w = heapq.heappop(heap)
        assign[v] = w
        remaining[w] -= 1
        if remaining[w] > 0:
            heapq.heappush(heap, (load + int(cost[v]), w))
    return assign


def choose_split(edge_load: np.ndarray, split_factor: float = 1.2
                 ) -> np.ndarray:
    """Physical shards per worker for ``balance="split"``: a worker whose
    edge load exceeds ``split_factor x`` the mean splits into
    ceil(load / (split_factor * mean)) equal-edge-count shards (each shard
    lands at or below the hot threshold); everyone else stays whole."""
    load = np.asarray(edge_load, np.float64)
    k = np.ones(len(load), np.int64)
    mean = load.mean() if load.size else 0.0
    if mean <= 0:
        return k
    target = split_factor * mean
    hot = load > target
    k[hot] = np.ceil(load[hot] / target).astype(np.int64)
    return k


def pair_weight(M: int, hosts: Optional[int] = None,
                cross_host_weight: float = 4.0) -> np.ndarray:
    """(M, M) per-worker-pair lane price for the crossness objective:
    0 on the diagonal (intra-worker messages never hit a wire), 1 for a
    cross-worker pair, ``cross_host_weight`` for a pair straddling two
    host blocks of M/H workers (the hierarchical mesh's expensive axis —
    refinement should prefer un-crossing a host link over a device
    link)."""
    W = np.ones((M, M), np.float64)
    if hosts is not None and hosts > 1:
        if M % hosts:
            raise ValueError(f"M={M} workers must divide over "
                             f"hosts={hosts}")
        hid = np.arange(M) // (M // hosts)
        W[hid[:, None] != hid[None, :]] = float(cross_host_weight)
    np.fill_diagonal(W, 0.0)
    return W


def crossness(pair_counts: np.ndarray,
              weight: Optional[np.ndarray] = None) -> float:
    """The locality objective ``refine_assignment`` descends: the
    weighted count of distinct cross-worker (source worker, destination
    vertex) pairs — exactly the combined messages a full broadcast
    superstep puts on the wire (``pair_counts`` IS that matrix)."""
    pc = np.asarray(pair_counts, np.float64)
    if weight is None:
        weight = pair_weight(len(pc))
    return float((pc * weight).sum())


def refine_assignment(src: np.ndarray, dst: np.ndarray,
                      assign: np.ndarray, M: int, cap: int,
                      cost: np.ndarray,
                      weight: Optional[np.ndarray] = None,
                      rounds: int = 3) -> tuple:
    """Greedy locality refinement of a vertex->worker assignment:
    move (or swap) vertices toward the worker holding most of their
    neighbors, strictly descending the ``crossness`` objective
    (distinct (source worker, destination vertex) pairs, weighted by
    ``weight``) while never exceeding the ``greedy_assign``
    constraints — at most ``cap`` vertices per worker and never
    raising the max per-worker ``cost`` load above its starting value
    (equal-or-better balance by construction).

    Each round evaluates every vertex's gain against a frozen snapshot
    (vectorized over the deduplicated edge list), then applies the
    candidate moves in descending-gain order with an EXACT incremental
    re-check, so interacting moves can never ascend the objective.  A
    move blocked by a full target worker (the common case: when M
    divides n every slot is taken) is retried as a SWAP with the best
    opposite-direction candidate, committed only if the exact combined
    gain still descends.  Returns ``(assign, n_moves)``.
    """
    n = len(assign)
    owner = np.asarray(assign, np.int64).copy()
    cost = np.asarray(cost, np.int64)
    # distinct directed pairs only (parallel edges don't add crossness);
    # self-loops move with their vertex and never cross
    key = np.unique(np.asarray(src, np.int64) * n
                    + np.asarray(dst, np.int64))
    es = key // n
    ed = key % n
    keep = es != ed
    es, ed = es[keep], ed[keep]
    order_e = np.argsort(es, kind="stable")
    es, ed = es[order_e], ed[order_e]
    indptr = np.searchsorted(es, np.arange(n + 1))

    W = pair_weight(M) if weight is None else np.asarray(weight,
                                                         np.float64)
    # C[u, w] = # distinct in-neighbors of u on worker w: pair (w, u)
    # exists iff C[u, w] > 0
    C = np.zeros((n, M), np.int32)
    np.add.at(C, (ed, owner[es]), 1)
    loads = np.zeros(M, np.int64)
    np.add.at(loads, owner, cost)
    slots = np.bincount(owner, minlength=M)
    load_cap = int(loads.max(initial=0))
    rows = np.arange(n)
    total_moves = 0

    def _exact_gain(v, av, bv):
        # J-delta of moving v: av -> bv under the CURRENT C/owner
        nzw = np.flatnonzero(C[v])
        g = W[nzw, av].sum() - W[nzw, bv].sum()
        nb = ed[indptr[v]:indptr[v + 1]]
        onb = owner[nb]
        g += ((C[nb, av] == 1) * W[av, onb]).sum()
        g -= ((C[nb, bv] == 0) * W[bv, onb]).sum()
        return g

    def _apply(v, av, bv):
        owner[v] = bv
        loads[av] -= cost[v]
        loads[bv] += cost[v]
        slots[av] -= 1
        slots[bv] += 1
        nb = ed[indptr[v]:indptr[v + 1]]
        np.add.at(C, (nb, av), -1)
        np.add.at(C, (nb, bv), 1)

    for _ in range(max(int(rounds), 0)):
        # frozen sweep: J-delta of moving v from a=owner[v] to its
        # dominant in-neighbor worker b, in two exact parts —
        #  1. v as destination: pairs (s, v) reprice from W[s, a] to
        #     W[s, b] over v's distinct in-neighbor workers s;
        #  2. v as source: for each out-neighbor u, pair (a, u) drops
        #     iff v was a's last in-edge of u, pair (b, u) appears iff
        #     b had none
        Z = (C > 0).astype(np.float64) @ W
        a = owner
        cand = np.argmax(C, axis=1).astype(np.int64)
        gain = Z[rows, a] - Z[rows, cand]
        a_e, b_e, o_u = a[es], cand[es], a[ed]
        part2 = ((C[ed, a_e] == 1) * W[a_e, o_u]
                 - (C[ed, b_e] == 0) * W[b_e, o_u])
        np.add.at(gain, es, part2)
        todo = np.flatnonzero((cand != a) & (C[rows, cand] > 0)
                              & (gain > 1e-9))
        todo = todo[np.argsort(-gain[todo], kind="stable")]
        # opposite-direction swap partners, best gain first, keyed by
        # the FROZEN (from, to) direction (staleness re-checked at pop)
        partners: dict = {}
        for v in todo:
            partners.setdefault((int(a[v]), int(cand[v])),
                                []).append(int(v))
        heads = {k: 0 for k in partners}
        moved = np.zeros(n, bool)
        moves = 0
        for v in todo:
            if moved[v]:
                continue
            av, bv = int(owner[v]), int(cand[v])
            if av == bv:
                continue
            # exact re-check under the CURRENT state (earlier moves in
            # this sweep may have changed both terms)
            g = _exact_gain(v, av, bv)
            if g <= 1e-9:
                continue
            if slots[bv] < cap and loads[bv] + cost[v] <= load_cap:
                _apply(v, av, bv)
                moved[v] = True
                moves += 1
                continue
            # target full: pair with the best reverse-direction (bv ->
            # av) candidate u; a swap keeps slot counts and is accepted
            # only if the exact COMBINED gain descends and neither
            # worker's load exceeds its cap
            queue = partners.get((bv, av))
            if queue is None:
                continue
            _apply(v, av, bv)  # tentative (slots may sit at cap + 1)
            done = False
            for _try in range(4):
                i = heads[(bv, av)]
                if i >= len(queue):
                    break
                u = queue[i]
                heads[(bv, av)] = i + 1
                if moved[u] or u == v or int(owner[u]) != bv:
                    continue
                if (loads[bv] - cost[u] > load_cap
                        or loads[av] + cost[u] > load_cap):
                    continue
                gu = _exact_gain(u, bv, av)
                if g + gu > 1e-9:
                    _apply(u, bv, av)
                    moved[v] = moved[u] = True
                    moves += 2
                    done = True
                break
            if not done:
                _apply(v, bv, av)  # revert the tentative half
        total_moves += moves
        if not moves:
            break
    return owner, total_moves


def worker_affinity(pair_counts: np.ndarray) -> np.ndarray:
    """Symmetric (M, M) worker communication affinity from the partition's
    distinct (source worker, destination vertex) pair matrix: traffic in
    either direction counts (the exchange is bidirectional wire either
    way) and self-traffic is zeroed (it never crosses a link).  Mirror
    broadcasts ride the same matrix — ``pair_counts`` is built over the
    full adjacency, so a heavy mirror pair shows up as a heavy entry."""
    pc = np.asarray(pair_counts, np.int64)
    aff = pc + pc.T
    np.fill_diagonal(aff, 0)
    return aff


def affinity_groups(aff: np.ndarray, H: int) -> np.ndarray:
    """Group M workers into H equal host blocks with high intra-block
    affinity — the placement knob of the hierarchical (host, device)
    mesh, which maps worker block ``[h*T, (h+1)*T)`` onto host h, so
    intra-block traffic rides the cheap intra-host level.

    Greedy: each block is seeded with the heaviest-affinity unassigned
    pair, then absorbs the unassigned worker with the largest affinity
    to the block until full.  Falls back to the identity (contiguous)
    grouping when greedy does not strictly beat it, so host-aware
    placement never scores below host-oblivious placement in the
    affinity proxy.  Returns the (M,) worker order, host by host: the
    worker at position i gets new id i."""
    aff = np.asarray(aff, np.float64)
    M = len(aff)
    if H <= 0 or M % H:
        raise ValueError(f"M={M} workers must divide over hosts={H}")
    T = M // H
    left = list(range(M))
    order = []
    for _ in range(H):
        rem = np.asarray(left)
        sub = aff[np.ix_(rem, rem)].copy()
        np.fill_diagonal(sub, -1.0)
        i, j = np.unravel_index(int(sub.argmax()), sub.shape)
        grp = [int(rem[i])] if T == 1 else [int(rem[i]), int(rem[j])]
        while len(grp) < T:
            cand = np.asarray([w for w in left if w not in grp])
            scores = aff[np.ix_(cand, np.asarray(grp))].sum(axis=1)
            grp.append(int(cand[int(scores.argmax())]))
        order += sorted(grp)  # stable ids within a host
        left = [w for w in left if w not in grp]
    greedy = np.asarray(order, np.int64)
    ident = np.arange(M, dtype=np.int64)

    def intra(o):
        return sum(aff[np.ix_(o[h * T:(h + 1) * T],
                              o[h * T:(h + 1) * T])].sum()
                   for h in range(H))

    return greedy if intra(greedy) > intra(ident) else ident


def contiguous_bounds(loads: np.ndarray, D: int) -> np.ndarray:
    """Partition a run of shard ``loads`` into D contiguous non-empty
    groups minimizing the max group load (binary search on the bottleneck
    + greedy feasibility).  Returns (D+1,) shard-index bounds."""
    loads = np.asarray(loads, np.int64)
    P = len(loads)
    if P < D:
        raise ValueError(f"{P} shards < {D} devices")
    prefix = np.concatenate([[0], np.cumsum(loads)])

    def bounds_for(cap):
        b = [0]
        for d in range(D):
            s = b[-1]
            # furthest end within cap that still leaves >=1 shard per
            # remaining group
            e_max = P - (D - d - 1)
            e = int(np.searchsorted(prefix, prefix[s] + cap, side="right")
                    ) - 1
            e = min(max(e, s + 1), e_max)
            b.append(e)
        return np.asarray(b, np.int64) if b[-1] == P else None

    lo = max(int(loads.max(initial=0)), -(-int(prefix[-1]) // D))
    hi = int(prefix[-1]) or 1
    while lo < hi:
        mid = (lo + hi) // 2
        if bounds_for(mid) is None:
            lo = mid + 1
        else:
            hi = mid
    out = bounds_for(lo)
    assert out is not None
    return out


def predicted_balance(cost: np.ndarray, assign: np.ndarray,
                      M: int) -> Dict[str, float]:
    """Balance predictor: the straggler report the cost model *expects*
    from an assignment, before any graph arrays are built."""
    loads = np.bincount(np.asarray(assign), weights=np.asarray(cost,
                                                               np.float64),
                        minlength=M)
    return straggler_report(loads)


def straggler_report(per_worker_msgs: np.ndarray) -> Dict[str, float]:
    """Imbalance metrics for a per-worker load histogram (Figs. 1/2):
    a worker 2x over the mean is a 2x straggler in a synchronous step."""
    m = np.asarray(per_worker_msgs, np.float64)
    mean = m.mean() if m.size else 0.0
    return {
        "max_over_mean": float(m.max() / mean) if mean > 0 else 0.0,
        "cv": float(m.std() / mean) if mean > 0 else 0.0,
        "gini": _gini(m),
    }


def _gini(x: np.ndarray) -> float:
    if x.sum() == 0:
        return 0.0
    xs = np.sort(x)
    n = len(xs)
    cum = np.cumsum(xs)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


# ---------------------------------------------------------------------------
# Theorem-2 analog for MoE expert mirroring (DESIGN.md §4)
# ---------------------------------------------------------------------------

def moe_mirror_threshold(tokens_per_rank: int, ep_size: int, d_model: int,
                         d_ff: int, steps_between_rebalance: int = 1,
                         flops_per_byte: float = 240.0) -> float:
    """Expert-mirroring break-even load (tokens/step routed to the expert).

    Mirroring an expert costs (a) broadcasting its weights (3*d_model*d_ff
    values / ``steps_between_rebalance`` steps, times ep_size ranks) and
    (b) — measured in §Perf iteration 3, REFUTED there for balanced
    routers — the dense-gated overcompute: every rank runs the mirrored
    expert over ALL its local tokens, 6*d_model*d_ff flops each, converted
    to byte-equivalents via the hardware flops/byte ratio.  It saves moving
    the expert's remote tokens (d_model values, dispatch + combine).

    Break-even: load * 2 * d_model * (1 - 1/ep_size)
                >= 3*d_model*d_ff*ep_size/steps
                   + tokens_per_rank * 6*d_model*d_ff / flops_per_byte.
    For aux-loss-balanced routers load ≈ tokens_per_rank*k/E stays far
    below this threshold — mirroring only pays under real skew, exactly
    the paper's Theorem-2 regime.
    """
    save_per_token = 2.0 * d_model * (1.0 - 1.0 / ep_size)
    bcast = 3.0 * d_model * d_ff * ep_size / max(steps_between_rebalance, 1)
    overcompute = tokens_per_rank * 6.0 * d_model * d_ff / flops_per_byte
    return (bcast + overcompute) / save_per_token

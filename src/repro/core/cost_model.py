"""The paper's cost model: Theorems 1-3 and the mirroring threshold.

Theorem 1: with mirroring, a vertex v delivers a(v) to all neighbors with
           <= min(M, d(v)) messages.
Theorem 2: mirror v iff d(v) >= tau* = M * exp(deg_avg / M)  (the point
           where mirroring beats sender-side combining in expectation).
Theorem 3: request-respond serves l requesters of one target with
           2*min(M, l) messages instead of 2*l.

``moe_mirror_threshold`` transfers Theorem 2 to expert parallelism: an
expert whose per-step routed-token load exceeds the threshold is cheaper to
replicate (mirror) on every EP rank than to keep exchanging tokens.
"""
from __future__ import annotations

import math

import numpy as np


def mirror_threshold(M: int, deg_avg: float) -> float:
    """Theorem 2: tau* = M * exp(deg_avg / M)."""
    return M * math.exp(deg_avg / M)


def thm1_bound(M: int, degree: int) -> int:
    return min(M, degree)


def thm3_bound(M: int, n_requesters: int) -> int:
    return 2 * min(M, n_requesters)


def expected_messages_combined(deg: np.ndarray, M: int) -> float:
    """Expected #messages for one all-neighbors broadcast through the
    combined channel under the paper's random-graph model: each vertex's
    message to a neighbor survives combining with prob exp(-deg_avg/M)
    (proof of Thm 2)."""
    deg_avg = float(deg.mean())
    return float(deg.sum() * math.exp(-deg_avg / M))


def expected_messages_mirrored(deg: np.ndarray, M: int, tau: float) -> float:
    """Expected #messages when vertices with d >= tau are mirrored."""
    hi = deg >= tau
    deg_avg = float(deg.mean())
    lo_msgs = float(deg[~hi].sum() * math.exp(-deg_avg / M))
    hi_msgs = float(np.minimum(deg[hi], M).sum())
    return lo_msgs + hi_msgs


def choose_tau(deg: np.ndarray, M: int) -> int:
    """The cost model's automatic threshold (rounded)."""
    return int(round(mirror_threshold(M, float(deg.mean()))))


# ---------------------------------------------------------------------------
# Theorem-2 analog for MoE expert mirroring (DESIGN.md §4)
# ---------------------------------------------------------------------------

def moe_mirror_threshold(tokens_per_rank: int, ep_size: int, d_model: int,
                         d_ff: int, steps_between_rebalance: int = 1,
                         flops_per_byte: float = 240.0) -> float:
    """Expert-mirroring break-even load (tokens/step routed to the expert).

    Mirroring an expert costs (a) broadcasting its weights (3*d_model*d_ff
    values / ``steps_between_rebalance`` steps, times ep_size ranks) and
    (b) — measured in §Perf iteration 3, REFUTED there for balanced
    routers — the dense-gated overcompute: every rank runs the mirrored
    expert over ALL its local tokens, 6*d_model*d_ff flops each, converted
    to byte-equivalents via the hardware flops/byte ratio.  It saves moving
    the expert's remote tokens (d_model values, dispatch + combine).

    Break-even: load * 2 * d_model * (1 - 1/ep_size)
                >= 3*d_model*d_ff*ep_size/steps
                   + tokens_per_rank * 6*d_model*d_ff / flops_per_byte.
    For aux-loss-balanced routers load ≈ tokens_per_rank*k/E stays far
    below this threshold — mirroring only pays under real skew, exactly
    the paper's Theorem-2 regime.
    """
    save_per_token = 2.0 * d_model * (1.0 - 1.0 / ep_size)
    bcast = 3.0 * d_model * d_ff * ep_size / max(steps_between_rebalance, 1)
    overcompute = tokens_per_rank * 6.0 * d_model * d_ff / flops_per_byte
    return (bcast + overcompute) / save_per_token
